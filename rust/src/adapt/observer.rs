//! The sampled, per-node streaming statistics tap ([`Observer`]) and the
//! engine adapter that feeds it ([`ObservedEngine`]).
//!
//! Sessions report one [`RunTap`] per *sampled* request (1-in-`sample_every`
//! — unsampled requests pay a single atomic increment, which is the
//! "near-zero hot-path cost" contract). The observer folds taps into a
//! mergeable [`Accumulator`]: per node, the integer `S1`/`S2` window sums of
//! [`WindowStats`] plus a clip counter (values on the grid extremes — the
//! paper's γ-coverage knob made observable). A bounded uniform reservoir of
//! sampled input images (Vitter's Algorithm R, seeded) rides along as the
//! live calibration set for full-rebuild recalibration backends.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::{Engine, EngineError, RunTap, Session};
use crate::engine::VariantSpec;
use crate::estimator::fixed::WindowStats;
use crate::nn::LiveNodeStats;
use crate::tensor::{Shape, Tensor};

use super::drift::{DriftConfig, TwoWindowConfig, TwoWindowEstimator, TwoWindowReport};

/// Observation knobs.
#[derive(Clone, Copy, Debug)]
pub struct ObserverConfig {
    /// Tap every Nth request (1 = every request). Unsampled requests cost
    /// one atomic increment.
    pub sample_every: u32,
    /// γ stride for the tap's window statistics (independent of the
    /// serving estimator's γ, so observation can be cheaper).
    pub tap_gamma: usize,
    /// Capacity of the live-input reservoir (the paper's shared
    /// calibration-set size by default).
    pub reservoir_cap: usize,
    /// Rotate (reset) the live window once it holds this many sampled
    /// requests without a recalibration consuming it — bounds staleness so
    /// the drift score tracks *recent* traffic instead of a lifetime
    /// average ([`crate::adapt::AdaptManager::tick`] enforces it).
    pub window_cap: u64,
    /// Two-window drift estimation (fast + slow rolling windows; the
    /// default detector input). `None` falls back to the single-window
    /// snapshot-vs-reference comparison, kept for A/B comparison.
    pub two_window: Option<TwoWindowConfig>,
}

impl Default for ObserverConfig {
    fn default() -> Self {
        Self {
            sample_every: 4,
            tap_gamma: 4,
            reservoir_cap: crate::engine::CALIB_SIZE,
            window_cap: 512,
            two_window: Some(TwoWindowConfig::default()),
        }
    }
}

/// One node's merged statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeAccum {
    /// Grid scale the integer sums were accumulated on (stable within an
    /// epoch; used to convert sums to real units).
    pub scale: f32,
    /// Pooled window accumulators across every sampled request.
    pub window: WindowStats,
    /// Output values observed on the grid extremes.
    pub clipped: u64,
    /// Total output values inspected.
    pub total: u64,
}

impl NodeAccum {
    /// Fold another accumulator of the same node into this one.
    pub fn merge(&mut self, other: &NodeAccum) {
        if self.total == 0 && self.window.n == 0 {
            self.scale = other.scale;
        }
        self.window.n += other.window.n;
        self.window.sum_s1 += other.window.sum_s1;
        self.window.sum_s2 += other.window.sum_s2;
        self.window.sum_s1_sq += other.window.sum_s1_sq;
        self.clipped += other.clipped;
        self.total += other.total;
    }

    /// Fraction of observed output values on the grid extremes.
    pub fn clip_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.clipped as f64 / self.total as f64
        }
    }

    /// Grid-independent real-unit features for drift comparison.
    pub fn features(&self) -> NodeFeatures {
        let n = self.window.n.max(1) as f64;
        NodeFeatures {
            mean_s1: self.scale as f64 * self.window.sum_s1 as f64 / n,
            mean_s2: (self.scale as f64).powi(2) * self.window.sum_s2 as f64 / n,
            clip_rate: self.clip_rate(),
        }
    }
}

/// Real-unit summary of one node's window: mean window sum, mean window
/// energy, and the clip rate. Comparable across recalibration epochs
/// (grids change, real units don't).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeFeatures {
    /// `scale · mean(S1)` — the mean window sum in real units.
    pub mean_s1: f64,
    /// `scale² · mean(S2)` — the mean window energy in real units.
    pub mean_s2: f64,
    /// Fraction of output values on the grid extremes.
    pub clip_rate: f64,
}

/// A mergeable window of per-node statistics over some span of sampled
/// requests.
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    /// Sampled requests folded into this window.
    pub requests: u64,
    /// Per-node statistics, keyed by graph node id.
    pub nodes: BTreeMap<usize, NodeAccum>,
}

impl Accumulator {
    /// Fold one run's tap into the window.
    pub fn absorb(&mut self, tap: &RunTap) {
        self.requests += 1;
        for nt in &tap.nodes {
            let e = self.nodes.entry(nt.node).or_default();
            e.merge(&NodeAccum {
                scale: nt.scale,
                window: nt.window,
                clipped: nt.clipped,
                total: nt.total,
            });
        }
    }

    /// Fold a whole other window into this one.
    pub fn merge(&mut self, other: &Accumulator) {
        self.requests += other.requests;
        for (node, acc) in &other.nodes {
            self.nodes.entry(*node).or_default().merge(acc);
        }
    }

    /// Real-unit features per node.
    pub fn features(&self) -> BTreeMap<usize, NodeFeatures> {
        self.nodes.iter().map(|(n, a)| (*n, a.features())).collect()
    }

    /// The raw pooled window statistics per node.
    pub fn window_stats(&self) -> BTreeMap<usize, WindowStats> {
        self.nodes.iter().map(|(n, a)| (*n, a.window)).collect()
    }

    /// Pooled window statistics *plus* observed clip rates per node (what
    /// [`crate::nn::Int8Executor::refit_static_grids`] consumes: the clip
    /// rate drives the Eq. 13 interval refit, the window drives the grid).
    pub fn live_stats(&self) -> BTreeMap<usize, LiveNodeStats> {
        self.nodes
            .iter()
            .map(|(n, a)| {
                (*n, LiveNodeStats { window: a.window, clip_rate: a.clip_rate() as f32 })
            })
            .collect()
    }

    /// The largest per-node clip rate in the window.
    pub fn max_clip_rate(&self) -> f64 {
        self.nodes.values().map(|a| a.clip_rate()).fold(0.0, f64::max)
    }

    /// Whether any statistics were collected.
    pub fn is_empty(&self) -> bool {
        self.requests == 0
    }
}

/// Bounded uniform sample of live inputs (Algorithm R, seeded LCG — same
/// scheme as the metrics reservoirs, so runs are reproducible).
struct ImageReservoir {
    cap: usize,
    seen: u64,
    images: Vec<Tensor<f32>>,
    lcg: u64,
}

impl ImageReservoir {
    fn offer(&mut self, img: &Tensor<f32>) {
        if self.cap == 0 {
            return;
        }
        self.seen += 1;
        if self.images.len() < self.cap {
            self.images.push(img.clone());
            return;
        }
        self.lcg = self.lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let j = (self.lcg >> 16) % self.seen;
        if (j as usize) < self.cap {
            self.images[j as usize] = img.clone();
        }
    }
}

/// The per-variant streaming statistics tap (see module docs).
pub struct Observer {
    cfg: ObserverConfig,
    seen: AtomicU64,
    accum: Mutex<Accumulator>,
    reservoir: Mutex<ImageReservoir>,
    two_window: Option<Mutex<TwoWindowEstimator>>,
}

impl Observer {
    /// A fresh observer.
    pub fn new(cfg: ObserverConfig) -> Observer {
        Observer {
            cfg,
            seen: AtomicU64::new(0),
            accum: Mutex::new(Accumulator::default()),
            reservoir: Mutex::new(ImageReservoir {
                cap: cfg.reservoir_cap,
                seen: 0,
                images: Vec::new(),
                lcg: 0x0B5E_12E5 | 1,
            }),
            two_window: cfg.two_window.map(|tw| Mutex::new(TwoWindowEstimator::new(tw))),
        }
    }

    /// The observation knobs.
    pub fn config(&self) -> &ObserverConfig {
        &self.cfg
    }

    /// Sampling decision for the next request (one atomic increment).
    pub fn should_sample(&self) -> bool {
        let n = self.seen.fetch_add(1, Ordering::Relaxed);
        self.cfg.sample_every <= 1 || n % self.cfg.sample_every as u64 == 0
    }

    /// Requests seen (sampled or not).
    pub fn requests_seen(&self) -> u64 {
        self.seen.load(Ordering::Relaxed)
    }

    /// Fold a sampled run's tap into the live window (and, when enabled,
    /// into the fast/slow rolling windows of the two-window estimator).
    pub fn absorb(&self, tap: &RunTap) {
        self.accum.lock().unwrap().absorb(tap);
        if let Some(tw) = &self.two_window {
            tw.lock().unwrap().absorb(tap);
        }
    }

    /// Fast/slow drift report from the two-window estimator, scored
    /// against `reference`. `None` when the estimator is disabled
    /// ([`ObserverConfig::two_window`] is `None`) — callers then fall
    /// back to the single-window snapshot comparison.
    pub fn two_window_report(
        &self,
        reference: &Accumulator,
        cfg: &DriftConfig,
    ) -> Option<TwoWindowReport> {
        self.two_window.as_ref().map(|tw| tw.lock().unwrap().report(reference, cfg))
    }

    /// Clear both rolling windows (after a successful recalibration the
    /// old windows describe the *previous* grids). No-op when disabled.
    pub fn reset_two_window(&self) {
        if let Some(tw) = &self.two_window {
            tw.lock().unwrap().reset();
        }
    }

    /// Offer a sampled input to the live-image reservoir.
    pub fn offer_image(&self, img: &Tensor<f32>) {
        self.reservoir.lock().unwrap().offer(img);
    }

    /// A copy of the current live window.
    pub fn snapshot(&self) -> Accumulator {
        self.accum.lock().unwrap().clone()
    }

    /// Take the live window, leaving an empty one (the recalibration
    /// hand-off point).
    pub fn take_window(&self) -> Accumulator {
        std::mem::take(&mut *self.accum.lock().unwrap())
    }

    /// Return a previously taken window (a recalibration that failed must
    /// not lose the statistics it consumed).
    pub fn merge_back(&self, window: Accumulator) {
        self.accum.lock().unwrap().merge(&window);
    }

    /// The current live-image reservoir (uniform over the sampled inputs
    /// offered since the last [`Observer::reset_reservoir`]).
    pub fn reservoir_images(&self) -> Vec<Tensor<f32>> {
        self.reservoir.lock().unwrap().images.clone()
    }

    /// Reservoir fill, without cloning any images (status endpoints poll
    /// this on every scrape).
    pub fn reservoir_len(&self) -> usize {
        self.reservoir.lock().unwrap().images.len()
    }

    /// Empty the reservoir so it re-fills from current traffic. Called
    /// alongside window rotation and after a successful recalibration —
    /// a lifetime-uniform sample would hand a later rebuild mostly
    /// pre-drift images, exactly the staleness the window rotation exists
    /// to bound.
    pub fn reset_reservoir(&self) {
        let mut r = self.reservoir.lock().unwrap();
        r.images.clear();
        r.seen = 0;
    }
}

/// An [`Engine`] adapter that taps sampled requests into an [`Observer`].
///
/// Wrapping is transparent: spec, input shape, and — critically — the
/// outputs of every run are identical to the inner engine's
/// ([`Session::run_tapped`]'s contract). This is what
/// [`crate::adapt::AdaptManager`] publishes into a
/// [`crate::engine::EngineCell`], so serving workers observe traffic
/// without knowing adaptation exists.
pub struct ObservedEngine {
    inner: Arc<dyn Engine>,
    observer: Arc<Observer>,
}

impl ObservedEngine {
    /// Wrap `inner`, reporting sampled runs to `observer`.
    pub fn new(inner: Arc<dyn Engine>, observer: Arc<Observer>) -> ObservedEngine {
        ObservedEngine { inner, observer }
    }

    /// The wrapped engine.
    pub fn inner(&self) -> &Arc<dyn Engine> {
        &self.inner
    }
}

impl Engine for ObservedEngine {
    fn spec(&self) -> VariantSpec {
        self.inner.spec()
    }

    fn input_shape(&self) -> &Shape {
        self.inner.input_shape()
    }

    fn compile(&self) -> Result<Box<dyn Session>, EngineError> {
        Ok(Box::new(ObservedSession {
            tap: RunTap::new(self.observer.config().tap_gamma),
            inner: self.inner.compile()?,
            observer: Arc::clone(&self.observer),
        }))
    }
}

struct ObservedSession {
    inner: Box<dyn Session>,
    observer: Arc<Observer>,
    tap: RunTap,
}

impl Session for ObservedSession {
    fn run(&mut self, input: &Tensor<f32>) -> Result<Vec<Tensor<f32>>, EngineError> {
        if self.observer.should_sample() {
            let outputs = self.inner.run_tapped(input, &mut self.tap)?;
            self.observer.absorb(&self.tap);
            self.observer.offer_image(input);
            Ok(outputs)
        } else {
            self.inner.run(input)
        }
    }

    fn run_tapped(
        &mut self,
        input: &Tensor<f32>,
        tap: &mut RunTap,
    ) -> Result<Vec<Tensor<f32>>, EngineError> {
        self.inner.run_tapped(input, tap)
    }

    fn input_shape(&self) -> &Shape {
        self.inner.input_shape()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FloatEngine;
    use crate::nn::Graph;

    fn relu_engine() -> Arc<dyn Engine> {
        let mut g = Graph::new(Shape::hwc(2, 2, 1));
        let x = g.input();
        let r = g.relu(x);
        g.mark_output(r);
        Arc::new(FloatEngine::new(Arc::new(g)))
    }

    #[test]
    fn accumulator_merges_node_stats() {
        let mut tap = RunTap::new(1);
        let img = Tensor::from_vec(Shape::hwc(2, 2, 1), vec![0.0, 0.5, 1.0, 0.25]);
        tap.observe_input_grid(&img);
        let mut a = Accumulator::default();
        a.absorb(&tap);
        a.absorb(&tap);
        assert_eq!(a.requests, 2);
        let node0 = &a.nodes[&0];
        assert_eq!(node0.window.n, 2);
        assert_eq!(node0.total, 8);
        assert_eq!(node0.clipped, 4);
        // merge() == absorbing the same taps into one window.
        let mut b = Accumulator::default();
        b.absorb(&tap);
        let mut c = Accumulator::default();
        c.absorb(&tap);
        b.merge(&c);
        assert_eq!(b.nodes[&0].window.sum_s1, node0.window.sum_s1);
        assert_eq!(b.max_clip_rate(), node0.clip_rate());
    }

    #[test]
    fn sampling_rate_is_one_in_n() {
        let obs = Observer::new(ObserverConfig { sample_every: 4, ..Default::default() });
        let sampled = (0..100).filter(|_| obs.should_sample()).count();
        assert_eq!(sampled, 25);
        let every = Observer::new(ObserverConfig { sample_every: 1, ..Default::default() });
        assert_eq!((0..10).filter(|_| every.should_sample()).count(), 10);
    }

    #[test]
    fn take_window_resets_and_merge_back_restores() {
        let obs = Observer::new(ObserverConfig { sample_every: 1, ..Default::default() });
        let mut tap = RunTap::new(1);
        tap.observe_input_grid(&Tensor::full(Shape::hwc(2, 2, 1), 0.5));
        obs.absorb(&tap);
        let w = obs.take_window();
        assert_eq!(w.requests, 1);
        assert!(obs.snapshot().is_empty());
        obs.merge_back(w);
        assert_eq!(obs.snapshot().requests, 1);
    }

    #[test]
    fn reservoir_bounds_and_fills() {
        let obs = Observer::new(ObserverConfig {
            sample_every: 1,
            reservoir_cap: 4,
            ..Default::default()
        });
        for i in 0..32 {
            obs.offer_image(&Tensor::full(Shape::hwc(2, 2, 1), i as f32));
        }
        let imgs = obs.reservoir_images();
        assert_eq!(imgs.len(), 4);
        // Uniform over the stream: not frozen at the first four offers.
        assert!(imgs.iter().any(|t| t.data()[0] >= 4.0), "reservoir never displaced");
    }

    #[test]
    fn two_window_estimator_rides_absorb_and_is_optional() {
        let obs = Observer::new(ObserverConfig { sample_every: 1, ..Default::default() });
        let mut tap = RunTap::new(1);
        tap.observe_input_grid(&Tensor::from_vec(
            Shape::hwc(2, 2, 1),
            vec![0.0, 0.5, 1.0, 0.25],
        ));
        let mut reference = Accumulator::default();
        for _ in 0..16 {
            reference.absorb(&tap);
        }
        for _ in 0..16 {
            obs.absorb(&tap);
        }
        let cfg = DriftConfig::default();
        let rep = obs.two_window_report(&reference, &cfg).expect("two-window on by default");
        // Live traffic identical to the reference: neither window alarms.
        assert!(rep.fast.aggregate < cfg.threshold);
        assert!(rep.slow.aggregate < cfg.threshold);
        assert!(rep.combined().requests > 0, "rolling windows absorbed the taps");
        obs.reset_two_window();
        let after = obs.two_window_report(&reference, &cfg).unwrap();
        assert_eq!(after.fast.requests, 0, "reset must empty the rolling windows");

        let off = Observer::new(ObserverConfig { two_window: None, ..Default::default() });
        assert!(off.two_window_report(&reference, &cfg).is_none());
    }

    #[test]
    fn live_stats_carry_clip_rates() {
        let mut tap = RunTap::new(1);
        tap.observe_input_grid(&Tensor::from_vec(
            Shape::hwc(2, 2, 1),
            vec![0.0, 0.5, 1.0, 0.25],
        ));
        let mut a = Accumulator::default();
        a.absorb(&tap);
        let live = a.live_stats();
        let node0 = &live[&0];
        assert_eq!(node0.window.n, a.nodes[&0].window.n);
        assert!((node0.clip_rate as f64 - a.nodes[&0].clip_rate()).abs() < 1e-6);
    }

    #[test]
    fn observed_engine_is_transparent_and_counts() {
        let observer = Arc::new(Observer::new(ObserverConfig {
            sample_every: 2,
            ..Default::default()
        }));
        let inner = relu_engine();
        let wrapped = ObservedEngine::new(Arc::clone(&inner), Arc::clone(&observer));
        assert_eq!(wrapped.spec(), inner.spec());
        let mut plain = inner.compile().unwrap();
        let mut obs_session = wrapped.compile().unwrap();
        let img = Tensor::from_vec(Shape::hwc(2, 2, 1), vec![1.0, -2.0, 3.0, -4.0]);
        for _ in 0..8 {
            let a = obs_session.run(&img).unwrap();
            let b = plain.run(&img).unwrap();
            assert_eq!(a[0].data(), b[0].data(), "observation must not perturb outputs");
        }
        assert_eq!(observer.requests_seen(), 8);
        // 1-in-2 sampling tapped 4 of the 8 runs.
        assert_eq!(observer.snapshot().requests, 4);
        assert_eq!(observer.reservoir_images().len(), 4);
    }
}
