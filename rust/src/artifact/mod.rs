//! # `pdq::artifact` — compiled model artifacts (`pdq-artifact-v1`).
//!
//! A versioned on-disk format for **lowered, calibrated** serving programs,
//! so calibration and serving can run on different machines and an adapted
//! grid survives restart. One artifact carries a model's *entire* 13-cell
//! serving menu — fp32, the three fake-quant emulation modes, and the three
//! int8 modes at every truncation rung — from **one weight copy**: the
//! int8 kernel tensors are stored once and shared (`Arc`) across all three
//! int8 modes and all rungs at load, exactly like the in-process build.
//!
//! ## File layout
//!
//! ```text
//! ┌──────────────────────────────────────────────────────────────┐
//! │ magic "PDQA1\n" (6 B) │ manifest_len u32 LE │ manifest_crc u32 LE │
//! ├──────────────────────────────────────────────────────────────┤
//! │ manifest.json (UTF-8, pretty-printed, ≤ 16 MiB)              │
//! ├──────── zero pad to the next 64-byte file offset ────────────┤
//! │ payload: fixed-offset sections, each 64-byte aligned         │
//! │   w{i}/b{i}  f32 LE   float weights + biases (graph rebuild) │
//! │   k{i}       i8       symmetric int8 kernel (shared tensor)  │
//! │   rs{i}      i32 LE   FC weight row sums (linear only)       │
//! │   bq{i}      i32 LE   folded bias, static mode               │
//! │   rq{i}      i32 LE   Q31 requant (multiplier, shift) pairs  │
//! └──────────────────────────────────────────────────────────────┘
//! ```
//!
//! The manifest carries schema version, model id, artifact epoch, the graph
//! spec, per-variant wire names, input/output shapes, weight granularity,
//! the γ/bits/coverage knobs, calibration provenance (image count +
//! source), the PDQ estimator tables (per-layer frozen ranges and `(α, β)`
//! intervals, all f32 values as exact `to_bits` patterns), and a
//! per-section `{offset, length, crc32, dtype}` table. Fixed offsets mean
//! the payload can be mapped read-only — [`Backing`] wraps `mmap(2)` behind
//! a std-only `unsafe` shim with a plain-read fallback — so N serve
//! processes share the page cache for verification and load. (The executor
//! tensors themselves are `Vec`-backed today, so kernel bytes are copied
//! out of the map at load; fully zero-copy serving needs a tensor-storage
//! refactor and is tracked in ROADMAP.)
//!
//! Loading ([`ArtifactEngine`]) verifies magic, schema, manifest CRC,
//! per-section CRCs, and every structural/shape invariant **before**
//! constructing anything, and returns a typed [`ArtifactError`] — never a
//! panic — on hostile bytes (fuzzed in `testing::fuzz::target_manifest_json`
//! / `target_artifact_payload`). A loaded menu is bit-exact with the
//! in-process [`crate::engine::standard_menu`] build of the same model.

mod crc32;
mod inspect;
mod load;
mod manifest;
mod mmapfile;
mod pack;
mod payload;
mod sign;

pub use crc32::crc32;
pub use inspect::{inspect_bytes, inspect_bytes_with_key, inspect_path, inspect_path_with_key, InspectReport, SignatureStatus};
pub use load::ArtifactEngine;
pub use manifest::{
    menu_specs, CalibSpec, Int8LayerSpec, Manifest, NodeSpec, SectionDtype, SectionEntry,
    StaticSpec,
};
pub use mmapfile::Backing;
pub use pack::{pack_model, pack_to_file, repack, PackOptions};
pub use sign::{hmac_sha256, sha256, sign_artifact, split_trailer, verify_artifact, SIG_MAGIC, TRAILER_LEN};

/// Leading file magic: format family + container version + a newline so
/// accidental text-mode mangling breaks the magic, not the payload.
pub const MAGIC: [u8; 6] = *b"PDQA1\n";

/// Manifest schema identifier (the `"schema"` field).
pub const SCHEMA: &str = "pdq-artifact-v1";

/// Fixed header size: magic + manifest length (u32 LE) + manifest CRC32.
pub const HEADER_LEN: usize = MAGIC.len() + 4 + 4;

/// Alignment of the payload start (in-file) and of every section offset
/// (payload-relative). 64 B keeps any future SIMD load on a mapped payload
/// naturally aligned (mmap bases are page-aligned).
pub const ALIGN: usize = 64;

/// Manifest size cap: a hostile length prefix must not make the loader
/// allocate or parse unbounded bytes.
pub const MAX_MANIFEST_BYTES: usize = 16 << 20;

/// Graph node-count cap (hostile manifests; real models are ≪ this).
pub const MAX_NODES: usize = 512;

/// Section-count cap for the checksum table.
pub const MAX_SECTIONS: usize = 4096;

/// Per-dimension cap on any declared shape.
pub const MAX_DIM: usize = 1 << 20;

/// Per-tensor element-count cap (weights and inferred activations).
pub const MAX_TENSOR_ELEMS: usize = 1 << 26;

/// Cap on conv/pool geometry fields (kernel, stride, pad).
pub const MAX_GEOM: usize = 1 << 12;

/// Cap on the PDQ sampling stride γ.
pub const MAX_GAMMA: usize = 1 << 16;

/// Why an artifact could not be packed, verified, or loaded. Every failure
/// a hostile or truncated file can provoke is a variant here — the loader
/// never panics on request/file data.
#[derive(Clone, Debug, PartialEq)]
pub enum ArtifactError {
    /// Filesystem-level failure (open/read/write/map).
    Io(String),
    /// The leading bytes are not the `pdq-artifact-v1` magic.
    BadMagic,
    /// The file ends before a structurally required byte range.
    Truncated {
        /// Bytes the structure requires.
        need: usize,
        /// Bytes actually present.
        have: usize,
    },
    /// The declared manifest length exceeds [`MAX_MANIFEST_BYTES`].
    ManifestTooLarge {
        /// Declared manifest length.
        len: usize,
        /// The cap it exceeded.
        max: usize,
    },
    /// The manifest is not valid UTF-8/JSON, or a field is missing, of the
    /// wrong type, out of range, or inconsistent.
    BadManifest(String),
    /// The manifest parses but declares a different schema version.
    SchemaMismatch {
        /// The schema string the manifest declares.
        found: String,
    },
    /// A CRC32 does not match its recorded value (`"manifest"` or a
    /// payload section name).
    ChecksumMismatch {
        /// Which checksummed region failed.
        section: String,
    },
    /// The declared graph is structurally invalid (bad topology, shape
    /// inference failure, arity/geometry violation).
    BadGraph(String),
    /// The per-variant data is invalid (estimator tables, requant specs,
    /// variant list drift).
    BadVariant(String),
    /// Packing failed (uncalibrated source, cross-mode drift, bad knobs).
    Pack(String),
    /// A verification key was supplied but the artifact carries no
    /// signature trailer — an unsigned artifact in a signed deployment is
    /// a policy violation, not a soft downgrade.
    SignatureMissing,
    /// The keyed-hash trailer does not match the artifact bytes: the file
    /// was modified after signing, or signed with a different key. The
    /// CRC wall detects corruption; this detects tampering.
    SignatureMismatch,
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Io(e) => write!(f, "artifact io error: {e}"),
            ArtifactError::BadMagic => write!(f, "not a pdq artifact (bad magic)"),
            ArtifactError::Truncated { need, have } => {
                write!(f, "artifact truncated: need {need} bytes, have {have}")
            }
            ArtifactError::ManifestTooLarge { len, max } => {
                write!(f, "manifest length {len} exceeds cap {max}")
            }
            ArtifactError::BadManifest(why) => write!(f, "bad manifest: {why}"),
            ArtifactError::SchemaMismatch { found } => {
                write!(f, "schema mismatch: found {found:?}, want {SCHEMA:?}")
            }
            ArtifactError::ChecksumMismatch { section } => {
                write!(f, "checksum mismatch in section {section:?}")
            }
            ArtifactError::BadGraph(why) => write!(f, "bad graph spec: {why}"),
            ArtifactError::BadVariant(why) => write!(f, "bad variant data: {why}"),
            ArtifactError::Pack(why) => write!(f, "pack failed: {why}"),
            ArtifactError::SignatureMissing => {
                write!(f, "verification key given but artifact is unsigned")
            }
            ArtifactError::SignatureMismatch => {
                write!(f, "artifact signature does not match (tampered or wrong key)")
            }
        }
    }
}

impl std::error::Error for ArtifactError {}

impl From<std::io::Error> for ArtifactError {
    fn from(e: std::io::Error) -> Self {
        ArtifactError::Io(e.to_string())
    }
}
