//! Packing: calibrated build → `pdq-artifact-v1` bytes.
//!
//! One pack calibrates a single static-mode [`QuantExecutor`] (the
//! calibration products are mode-independent), *restores* that frozen
//! state into fresh dynamic/PDQ executors through the same
//! [`QuantExecutor::restore_calibration`] path the loader uses, lowers
//! all three to int8, and cross-checks every mode-shared lowered field
//! bitwise before serializing — so an artifact can only ever encode a
//! state all three modes agree on, and the single stored copy is provably
//! sufficient. The finished bytes are split + validated + CRC-verified
//! before being returned.

use std::path::Path;
use std::sync::Arc;

use super::crc32::crc32;
use super::load::{split_artifact, ArtifactEngine};
use super::manifest::{menu_specs, CalibSpec, Int8LayerSpec, Manifest, NodeSpec, StaticSpec};
use super::payload::PayloadWriter;
use super::{ArtifactError, ALIGN, HEADER_LEN, MAGIC, MAX_GAMMA, MAX_MANIFEST_BYTES};
use crate::engine::{calibration_images, CALIB_SIZE};
use crate::models::Model;
use crate::nn::graph::{Node, Op};
use crate::nn::int8_exec::{Int8Executor, Int8Layer, Int8Node, Int8Op};
use crate::nn::quant_exec::QuantSettings;
use crate::nn::{QuantExecutor, QuantMode};
use crate::quant::{Granularity, QParams};
use crate::tensor::Tensor;

/// Knobs of one pack run.
#[derive(Clone, Debug)]
pub struct PackOptions {
    /// Artifact epoch to stamp (≥ 1; `repack` bumps it).
    pub epoch: u64,
    /// Calibration provenance string for the manifest.
    pub calib_source: String,
    /// PDQ sampling stride γ.
    pub gamma: usize,
    /// Coverage quantile for interval calibration.
    pub coverage: f32,
    /// Weight-scale granularity of the int8 lowering.
    pub weight_gran: Granularity,
    /// Explicit calibration set; `None` draws `calib_size` task images.
    pub calib: Option<Vec<Tensor<f32>>>,
    /// Size of the drawn calibration set when `calib` is `None`.
    pub calib_size: usize,
}

impl Default for PackOptions {
    fn default() -> Self {
        Self {
            epoch: 1,
            calib_source: "task-calib".into(),
            gamma: 1,
            coverage: 0.9995,
            weight_gran: Granularity::PerTensor,
            calib: None,
            calib_size: CALIB_SIZE,
        }
    }
}

fn pack_err(why: impl Into<String>) -> ArtifactError {
    ArtifactError::Pack(why.into())
}

/// The lowered layer of a quantizable node, if any.
fn layer_of(node: &Int8Node) -> Option<&Int8Layer> {
    match &node.op {
        Int8Op::Conv { l, .. } | Int8Op::DwConv { l, .. } | Int8Op::Linear { l } => Some(l),
        _ => None,
    }
}

fn same_bits(a: f32, b: f32) -> bool {
    a.to_bits() == b.to_bits()
}

fn same_f32s(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| same_bits(*x, *y))
}

/// Bitwise equality of every mode-shared field of two lowerings. The
/// artifact stores these once; any drift would silently corrupt two of
/// the three modes at load, so packing refuses it outright.
fn cross_check(label: &str, a: &Int8Executor, b: &Int8Executor) -> Result<(), ArtifactError> {
    if a.nodes().len() != b.nodes().len() {
        return Err(pack_err(format!("{label}: lowered node counts differ")));
    }
    for (i, (na, nb)) in a.nodes().iter().zip(b.nodes()).enumerate() {
        let (la, lb) = match (layer_of(na), layer_of(nb)) {
            (None, None) => continue,
            (Some(la), Some(lb)) => (la, lb),
            _ => return Err(pack_err(format!("{label}: node {i} topology drift"))),
        };
        let shared_ok = la.kernel.shape() == lb.kernel.shape()
            && la.kernel.data() == lb.kernel.data()
            && same_f32s(&la.s_w, &lb.s_w)
            && same_f32s(&la.bias_f, &lb.bias_f)
            && la.w_row_sums == lb.w_row_sums
            && same_bits(la.mu_w, lb.mu_w)
            && same_bits(la.var_w, lb.var_w)
            && same_bits(la.bias_mu, lb.bias_mu)
            && same_bits(la.bias_var, lb.bias_var)
            && same_bits(la.interval.alpha, lb.interval.alpha)
            && same_bits(la.interval.beta, lb.interval.beta);
        if !shared_ok {
            return Err(pack_err(format!("{label}: node {i} cross-mode lowering drift")));
        }
    }
    Ok(())
}

/// Manifest node spec of a graph node.
fn node_spec(node: &Node) -> NodeSpec {
    let input = |i: usize| node.inputs[i].0;
    match &node.op {
        Op::Input => NodeSpec::Input,
        Op::Conv { w, geom, .. } => NodeSpec::Conv {
            input: input(0),
            wshape: w.shape().dims().to_vec(),
            stride: geom.stride,
            pad: geom.pad,
        },
        Op::DwConv { w, geom, .. } => NodeSpec::DwConv {
            input: input(0),
            wshape: w.shape().dims().to_vec(),
            stride: geom.stride,
            pad: geom.pad,
        },
        Op::Linear { w, .. } => {
            NodeSpec::Linear { input: input(0), wshape: w.shape().dims().to_vec() }
        }
        Op::Relu => NodeSpec::Relu { input: input(0) },
        Op::Relu6 => NodeSpec::Relu6 { input: input(0) },
        Op::MaxPool { k, stride } => {
            NodeSpec::MaxPool { input: input(0), k: *k, stride: *stride }
        }
        Op::GlobalAvgPool => NodeSpec::Gap { input: input(0) },
        Op::Flatten => NodeSpec::Flatten { input: input(0) },
        Op::Add => NodeSpec::Add { a: input(0), b: input(1) },
    }
}

/// Header + manifest + pad + payload → final file bytes. (`pub(crate)`:
/// loader tests reassemble tampered-but-CRC-consistent files with it.)
pub(crate) fn assemble(manifest: &Manifest, payload: &[u8]) -> Result<Vec<u8>, ArtifactError> {
    let text = manifest.to_json_text();
    if text.len() > MAX_MANIFEST_BYTES {
        return Err(pack_err(format!("manifest is {} bytes (cap {MAX_MANIFEST_BYTES})", text.len())));
    }
    let mut out = Vec::with_capacity(HEADER_LEN + text.len() + ALIGN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&(text.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(text.as_bytes()).to_le_bytes());
    out.extend_from_slice(text.as_bytes());
    while out.len() % ALIGN != 0 {
        out.push(0);
    }
    out.extend_from_slice(payload);
    Ok(out)
}

/// Pack a model into `pdq-artifact-v1` bytes: calibrate once, restore
/// into the other two modes, lower all three, cross-check, serialize,
/// and self-verify the finished bytes (split + validate + CRC) so a
/// packing bug can never produce a file the loader would trust.
pub fn pack_model(model: &Model, opts: PackOptions) -> Result<Vec<u8>, ArtifactError> {
    if opts.epoch == 0 {
        return Err(pack_err("epoch must be >= 1"));
    }
    if opts.gamma == 0 || opts.gamma > MAX_GAMMA {
        return Err(pack_err(format!("gamma outside 1..={MAX_GAMMA}")));
    }
    if !(opts.coverage.is_finite() && opts.coverage > 0.0 && opts.coverage < 1.0) {
        return Err(pack_err("coverage must be finite in (0, 1)"));
    }
    let images = match &opts.calib {
        Some(v) if v.is_empty() => return Err(pack_err("explicit calibration set is empty")),
        Some(v) => v.clone(),
        None => calibration_images(model.task, opts.calib_size.max(1)),
    };
    let graph = Arc::clone(&model.graph);
    let settings = |mode: QuantMode| QuantSettings {
        mode,
        granularity: Granularity::PerTensor,
        bits: 8,
        gamma: opts.gamma,
        coverage: opts.coverage,
    };

    // Calibrate once (static mode — the products are mode-independent).
    let mut calibrated = QuantExecutor::new(Arc::clone(&graph), settings(QuantMode::Static));
    calibrated.calibrate(&images);
    if !calibrated.is_calibrated() {
        return Err(pack_err("calibration left layers without frozen ranges"));
    }
    let qids: Vec<usize> = graph.quantizable_ids().iter().map(|id| id.0).collect();
    let mut calib_specs = Vec::with_capacity(qids.len());
    for &idx in &qids {
        let st = calibrated
            .layer_state(idx)
            .ok_or_else(|| pack_err(format!("node {idx}: missing layer state")))?;
        let ranges = st
            .static_ranges
            .clone()
            .ok_or_else(|| pack_err(format!("node {idx}: missing frozen ranges")))?;
        calib_specs.push(CalibSpec { node: idx, interval: st.interval, ranges });
    }

    // Restore into the other two modes through the loader's own path.
    let mut dynamic = QuantExecutor::new(Arc::clone(&graph), settings(QuantMode::Dynamic));
    let mut ours = QuantExecutor::new(Arc::clone(&graph), settings(QuantMode::Probabilistic));
    for c in &calib_specs {
        for ex in [&mut dynamic, &mut ours] {
            if !ex.restore_calibration(c.node, c.ranges.clone(), c.interval) {
                return Err(pack_err(format!("node {}: calibration restore refused", c.node)));
            }
        }
    }

    let low_s = Int8Executor::lower(&calibrated, opts.weight_gran).map_err(pack_err)?;
    let low_d = Int8Executor::lower(&dynamic, opts.weight_gran).map_err(pack_err)?;
    let low_p = Int8Executor::lower(&ours, opts.weight_gran).map_err(pack_err)?;
    cross_check("static vs dynamic", &low_s, &low_d)?;
    cross_check("static vs pdq", &low_s, &low_p)?;

    // Serialize from the static lowering (it carries the frozen extras).
    let mut int8_specs = Vec::with_capacity(qids.len());
    let mut writer = PayloadWriter::new();
    for &idx in &qids {
        let node = &graph.nodes()[idx];
        let (wt, bias) = match &node.op {
            Op::Conv { w, b, .. } | Op::DwConv { w, b, .. } | Op::Linear { w, b } => (w, b),
            _ => return Err(pack_err(format!("node {idx}: not quantizable"))),
        };
        let l = layer_of(&low_s.nodes()[idx])
            .ok_or_else(|| pack_err(format!("node {idx}: lowering lost the layer")))?;
        let out = l
            .static_out
            .ok_or_else(|| pack_err(format!("node {idx}: static lowering has no frozen grid")))?;
        let rq = l
            .static_requant
            .as_ref()
            .ok_or_else(|| pack_err(format!("node {idx}: static lowering has no requant spec")))?;
        int8_specs.push(Int8LayerSpec {
            node: idx,
            s_w: l.s_w.clone(),
            mu_w: l.mu_w,
            var_w: l.var_w,
            bias_mu: l.bias_mu,
            bias_var: l.bias_var,
            interval: l.interval,
            static_spec: StaticSpec {
                out_scale: out.scale,
                out_zero: out.zero,
                offset: rq.output_offset,
                act_min: rq.act_min,
                act_max: rq.act_max,
            },
        });
        writer.f32s(&format!("w{idx}"), wt.data());
        writer.f32s(&format!("b{idx}"), bias);
        writer.i8s(&format!("k{idx}"), l.kernel.data());
        if matches!(node.op, Op::Linear { .. }) {
            writer.i32s(&format!("rs{idx}"), &l.w_row_sums);
        }
        writer.i32s(&format!("bq{idx}"), &l.bias_q);
        let pairs: Vec<i32> = rq.multipliers.iter().flat_map(|m| [m.multiplier, m.shift]).collect();
        writer.i32s(&format!("rq{idx}"), &pairs);
    }
    let (payload, sections) = writer.finish();

    let (ilo, ihi) = calibrated.input_range();
    let input_qp = QParams::from_range(ilo, ihi, 8);
    let shapes = crate::nn::memory::infer_shapes(&graph);
    let outputs: Vec<usize> = graph.output_ids().iter().map(|id| id.0).collect();
    let output_shapes = outputs.iter().map(|&o| shapes[o].clone()).collect();
    let created_unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);

    let manifest = Manifest {
        model: model.name.clone(),
        epoch: opts.epoch,
        task: model.task,
        created_unix,
        input_shape: graph.input_shape().clone(),
        output_shapes,
        gamma: opts.gamma,
        coverage: opts.coverage,
        input_scale: input_qp.scale,
        input_zero: input_qp.zero_point,
        calib_images: images.len(),
        calib_source: opts.calib_source.clone(),
        nodes: graph.nodes().iter().map(node_spec).collect(),
        outputs,
        calib: calib_specs,
        weight_gran: opts.weight_gran,
        int8_layers: int8_specs,
        variants: menu_specs(opts.weight_gran).iter().map(|s| s.wire()).collect(),
        sections,
    };

    let bytes = assemble(&manifest, &payload)?;
    // Self-verify before handing the bytes out: a packing bug must fail
    // here, not at some future load.
    let (m2, pl) = split_artifact(&bytes)?;
    m2.validate(pl.len())?;
    m2.verify_sections(pl)?;
    Ok(bytes)
}

/// [`pack_model`] straight to a file.
pub fn pack_to_file(model: &Model, opts: PackOptions, path: &Path) -> Result<(), ArtifactError> {
    let bytes = pack_model(model, opts)?;
    std::fs::write(path, &bytes)?;
    Ok(())
}

/// Re-pack an artifact with a fresh calibration epoch: load (full
/// verification), re-calibrate the reconstructed model on fresh task
/// images, and emit epoch + 1 — how an adapted grid survives restart.
pub fn repack(bytes: &[u8]) -> Result<Vec<u8>, ArtifactError> {
    let eng = ArtifactEngine::from_bytes(bytes)?;
    let m = eng.manifest();
    let opts = PackOptions {
        epoch: m.epoch.saturating_add(1),
        calib_source: "repack".into(),
        gamma: m.gamma,
        coverage: m.coverage,
        weight_gran: m.weight_gran,
        calib: None,
        calib_size: m.calib_images,
    };
    pack_model(eng.model(), opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::calibrate::demo_model;

    #[test]
    fn pack_is_self_consistent_and_deterministic_sans_timestamp() {
        let model = demo_model("demo");
        let bytes = pack_model(&model, PackOptions::default()).unwrap();
        let (manifest, payload) = split_artifact(&bytes).unwrap();
        assert_eq!(manifest.model, model.name);
        assert_eq!(manifest.epoch, 1);
        assert_eq!(manifest.variants.len(), 13);
        manifest.validate(payload.len()).unwrap();
        manifest.verify_sections(payload).unwrap();
        // Manifest text round-trips losslessly.
        let text = manifest.to_json_text();
        assert_eq!(Manifest::parse(&text).unwrap().to_json_text(), text);
    }

    #[test]
    fn repack_bumps_epoch() {
        let model = demo_model("demo");
        let bytes = pack_model(&model, PackOptions::default()).unwrap();
        let again = repack(&bytes).unwrap();
        let (m2, _) = split_artifact(&again).unwrap();
        assert_eq!(m2.epoch, 2);
        assert_eq!(m2.calib_source, "repack");
    }

    #[test]
    fn bad_knobs_are_refused() {
        let model = demo_model("demo");
        let r = pack_model(&model, PackOptions { gamma: 0, ..PackOptions::default() });
        assert!(matches!(r, Err(ArtifactError::Pack(_))));
        let r = pack_model(&model, PackOptions { coverage: 1.5, ..PackOptions::default() });
        assert!(matches!(r, Err(ArtifactError::Pack(_))));
        let r = pack_model(&model, PackOptions { calib: Some(vec![]), ..PackOptions::default() });
        assert!(matches!(r, Err(ArtifactError::Pack(_))));
    }
}
