//! The typed manifest of a `pdq-artifact-v1` file.
//!
//! Everything the payload does not carry lives here: schema + identity,
//! the graph spec (weights live in the payload, referenced by convention
//! as `w{i}`/`b{i}`/`k{i}`/`rs{i}`/`bq{i}`/`rq{i}` sections), the frozen
//! calibration tables, the per-mode int8 lowering metadata, the canonical
//! variant list, and the section checksum table. Every `f32` is stored as
//! its exact `to_bits()` pattern (a `u32` integer — JSON numbers below
//! `1e15` round-trip exactly through the repo serializer), so a manifest
//! round-trip is bit-lossless.
//!
//! Parsing ([`Manifest::parse`]) is strict — `Json::as_usize` truncates
//! and saturates, so every numeric field goes through integer-checked,
//! range-capped helpers instead — and [`Manifest::validate`] re-derives
//! the whole structure (checked shape inference mirroring
//! [`crate::nn::memory::infer_shapes`], canonical section layout, variant
//! list) before a loader touches any payload byte. Hostile manifests get
//! typed [`ArtifactError`]s, never panics.

use super::crc32::crc32;
use super::{
    ArtifactError, ALIGN, MAX_DIM, MAX_GAMMA, MAX_GEOM, MAX_NODES, MAX_SECTIONS,
    MAX_TENSOR_ELEMS, SCHEMA,
};
use crate::data::Task;
use crate::engine::{VariantKey, VariantSpec};
use crate::estimator::IntervalSpec;
use crate::nn::QuantMode;
use crate::quant::Granularity;
use crate::tensor::Shape;
use crate::util::json::Json;

/// Cap on free-form manifest strings (calibration source, section names).
const MAX_STR: usize = 256;

/// Cap on |zero point| / |requant offset| integers. Real grids sit within
/// a few hundred of zero; the cap keeps hostile values from overflowing
/// debug-checked `i32` adds inside the executors.
const MAX_ZP: i64 = 1 << 20;

/// Element type of a payload section.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SectionDtype {
    /// Raw int8 (kernel tensors).
    I8,
    /// Little-endian `i32` (row sums, folded biases, requant pairs).
    I32,
    /// Little-endian `f32` (float weights and biases).
    F32,
}

impl SectionDtype {
    /// Wire spelling used in the manifest (`"i8" | "i32" | "f32"`).
    pub fn wire(self) -> &'static str {
        match self {
            SectionDtype::I8 => "i8",
            SectionDtype::I32 => "i32",
            SectionDtype::F32 => "f32",
        }
    }

    /// Inverse of [`SectionDtype::wire`].
    pub fn parse(s: &str) -> Option<SectionDtype> {
        match s {
            "i8" => Some(SectionDtype::I8),
            "i32" => Some(SectionDtype::I32),
            "f32" => Some(SectionDtype::F32),
            _ => None,
        }
    }

    /// Bytes per element.
    pub fn elem_size(self) -> usize {
        match self {
            SectionDtype::I8 => 1,
            SectionDtype::I32 | SectionDtype::F32 => 4,
        }
    }
}

/// One row of the payload checksum table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SectionEntry {
    /// Section name (`w{i}`, `b{i}`, `k{i}`, `rs{i}`, `bq{i}`, `rq{i}`).
    pub name: String,
    /// Payload-relative byte offset (always a multiple of [`ALIGN`]).
    pub off: usize,
    /// Byte length (unpadded).
    pub len: usize,
    /// CRC-32 of exactly `payload[off..off + len]`.
    pub crc: u32,
    /// Element type.
    pub dtype: SectionDtype,
}

/// A graph node as declared by the manifest. Weight *shapes* live here;
/// weight *values* live in the payload sections named after the node
/// index. Conv kernels are OHWI `[C_out, kh, kw, C_in]`, depthwise
/// `[C, kh, kw]`, linear `[h, d]` — `kh`/`kw` are read off the shape.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NodeSpec {
    /// The (single) graph input; must be node 0.
    Input,
    /// 2-D convolution with bias.
    Conv {
        /// Producing node of the activation input.
        input: usize,
        /// OHWI kernel shape.
        wshape: Vec<usize>,
        /// Spatial stride.
        stride: usize,
        /// Symmetric zero padding.
        pad: usize,
    },
    /// Depthwise convolution with bias.
    DwConv {
        /// Producing node of the activation input.
        input: usize,
        /// `[C, kh, kw]` kernel shape.
        wshape: Vec<usize>,
        /// Spatial stride.
        stride: usize,
        /// Symmetric zero padding.
        pad: usize,
    },
    /// Fully connected layer with bias.
    Linear {
        /// Producing node of the activation input.
        input: usize,
        /// `[h, d]` weight shape.
        wshape: Vec<usize>,
    },
    /// `max(0, x)`.
    Relu {
        /// Producing node.
        input: usize,
    },
    /// `min(max(0, x), 6)`.
    Relu6 {
        /// Producing node.
        input: usize,
    },
    /// Square-window max pooling (no padding).
    MaxPool {
        /// Producing node.
        input: usize,
        /// Window size.
        k: usize,
        /// Stride.
        stride: usize,
    },
    /// Global average pool, HWC → C.
    Gap {
        /// Producing node.
        input: usize,
    },
    /// HWC → flat vector.
    Flatten {
        /// Producing node.
        input: usize,
    },
    /// Elementwise residual add.
    Add {
        /// First operand node.
        a: usize,
        /// Second operand node.
        b: usize,
    },
}

impl NodeSpec {
    /// The op's wire name (matches [`crate::nn::Op::name`]).
    pub fn op_name(&self) -> &'static str {
        match self {
            NodeSpec::Input => "input",
            NodeSpec::Conv { .. } => "conv",
            NodeSpec::DwConv { .. } => "dwconv",
            NodeSpec::Linear { .. } => "linear",
            NodeSpec::Relu { .. } => "relu",
            NodeSpec::Relu6 { .. } => "relu6",
            NodeSpec::MaxPool { .. } => "maxpool",
            NodeSpec::Gap { .. } => "gap",
            NodeSpec::Flatten { .. } => "flatten",
            NodeSpec::Add { .. } => "add",
        }
    }

    /// Conv/dwconv/linear — the nodes with payload sections.
    pub fn is_quantizable(&self) -> bool {
        matches!(self, NodeSpec::Conv { .. } | NodeSpec::DwConv { .. } | NodeSpec::Linear { .. })
    }

    /// Declared weight shape, when quantizable.
    pub fn wshape(&self) -> Option<&[usize]> {
        match self {
            NodeSpec::Conv { wshape, .. }
            | NodeSpec::DwConv { wshape, .. }
            | NodeSpec::Linear { wshape, .. } => Some(wshape),
            _ => None,
        }
    }

    /// Input node ids in operand order (empty for `Input`).
    pub fn inputs(&self) -> Vec<usize> {
        match self {
            NodeSpec::Input => vec![],
            NodeSpec::Conv { input, .. }
            | NodeSpec::DwConv { input, .. }
            | NodeSpec::Linear { input, .. }
            | NodeSpec::Relu { input }
            | NodeSpec::Relu6 { input }
            | NodeSpec::MaxPool { input, .. }
            | NodeSpec::Gap { input }
            | NodeSpec::Flatten { input } => vec![*input],
            NodeSpec::Add { a, b } => vec![*a, *b],
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("op", self.op_name());
        match self {
            NodeSpec::Input => {}
            NodeSpec::Conv { input, wshape, stride, pad }
            | NodeSpec::DwConv { input, wshape, stride, pad } => {
                j.set("in", vec![*input])
                    .set("wshape", wshape.clone())
                    .set("stride", *stride)
                    .set("pad", *pad);
            }
            NodeSpec::Linear { input, wshape } => {
                j.set("in", vec![*input]).set("wshape", wshape.clone());
            }
            NodeSpec::Relu { input }
            | NodeSpec::Relu6 { input }
            | NodeSpec::Gap { input }
            | NodeSpec::Flatten { input } => {
                j.set("in", vec![*input]);
            }
            NodeSpec::MaxPool { input, k, stride } => {
                j.set("in", vec![*input]).set("k", *k).set("stride", *stride);
            }
            NodeSpec::Add { a, b } => {
                j.set("in", vec![*a, *b]);
            }
        }
        j
    }

    fn from_json(j: &Json, idx: usize) -> Result<NodeSpec, ArtifactError> {
        let ctx = format!("graph.nodes[{idx}]");
        let op = str_field(j, "op", &ctx)?;
        let one_in = |j: &Json| -> Result<usize, ArtifactError> {
            let arr = arr_field(j, "in", &ctx)?;
            if arr.len() != 1 {
                return Err(bad(&ctx, "expected exactly one input"));
            }
            usize_in(&arr[0], 0, MAX_NODES as i64, &ctx)
        };
        match op {
            "input" => Ok(NodeSpec::Input),
            "conv" | "dwconv" => {
                let input = one_in(j)?;
                let wshape = usize_arr(field(j, "wshape", &ctx)?, 8, MAX_DIM, &ctx)?;
                let stride = usize_in(field(j, "stride", &ctx)?, 1, MAX_GEOM as i64, &ctx)?;
                let pad = usize_in(field(j, "pad", &ctx)?, 0, MAX_GEOM as i64, &ctx)?;
                Ok(if op == "conv" {
                    NodeSpec::Conv { input, wshape, stride, pad }
                } else {
                    NodeSpec::DwConv { input, wshape, stride, pad }
                })
            }
            "linear" => {
                let input = one_in(j)?;
                let wshape = usize_arr(field(j, "wshape", &ctx)?, 8, MAX_DIM, &ctx)?;
                Ok(NodeSpec::Linear { input, wshape })
            }
            "relu" => Ok(NodeSpec::Relu { input: one_in(j)? }),
            "relu6" => Ok(NodeSpec::Relu6 { input: one_in(j)? }),
            "gap" => Ok(NodeSpec::Gap { input: one_in(j)? }),
            "flatten" => Ok(NodeSpec::Flatten { input: one_in(j)? }),
            "maxpool" => {
                let input = one_in(j)?;
                let k = usize_in(field(j, "k", &ctx)?, 1, MAX_GEOM as i64, &ctx)?;
                let stride = usize_in(field(j, "stride", &ctx)?, 1, MAX_GEOM as i64, &ctx)?;
                Ok(NodeSpec::MaxPool { input, k, stride })
            }
            "add" => {
                let arr = arr_field(j, "in", &ctx)?;
                if arr.len() != 2 {
                    return Err(bad(&ctx, "add expects exactly two inputs"));
                }
                let a = usize_in(&arr[0], 0, MAX_NODES as i64, &ctx)?;
                let b = usize_in(&arr[1], 0, MAX_NODES as i64, &ctx)?;
                Ok(NodeSpec::Add { a, b })
            }
            other => Err(bad(&ctx, &format!("unknown op {other:?}"))),
        }
    }
}

/// Frozen calibration table of one quantizable node — enough to restore
/// any of the three requantization modes without re-running calibration.
#[derive(Clone, Debug)]
pub struct CalibSpec {
    /// Node id this table belongs to.
    pub node: usize,
    /// PDQ interval multipliers `(α, β)` fitted at calibration.
    pub interval: IntervalSpec,
    /// Frozen activation ranges (per-tensor in v1: exactly one pair).
    pub ranges: Vec<(f32, f32)>,
}

/// Static-mode extras of one lowered int8 layer: the frozen output grid
/// and the identity of the payload `bq{i}`/`rq{i}` sections.
#[derive(Clone, Debug)]
pub struct StaticSpec {
    /// Frozen output scale.
    pub out_scale: f32,
    /// Frozen output zero point.
    pub out_zero: i32,
    /// Requant output offset (equals `out_zero` in v1).
    pub offset: i32,
    /// Post-requant clamp floor.
    pub act_min: i32,
    /// Post-requant clamp ceiling.
    pub act_max: i32,
}

/// Mode-shared int8 lowering metadata of one quantizable node. The kernel
/// itself is the payload `k{i}` section; this is everything scalar.
#[derive(Clone, Debug)]
pub struct Int8LayerSpec {
    /// Node id this layer belongs to.
    pub node: usize,
    /// Weight scales (1 entry per-tensor, `C_out` entries per-channel).
    pub s_w: Vec<f32>,
    /// Mean of the dequantized weights (PDQ surrogate).
    pub mu_w: f32,
    /// Variance of the dequantized weights (PDQ surrogate).
    pub var_w: f32,
    /// Mean of the float bias (PDQ surrogate).
    pub bias_mu: f32,
    /// Variance of the float bias (PDQ surrogate).
    pub bias_var: f32,
    /// PDQ interval multipliers (copied from the calibration table).
    pub interval: IntervalSpec,
    /// Static-mode frozen grid + requant identity.
    pub static_spec: StaticSpec,
}

/// The parsed, typed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Served model name (wire-name charset, ≤ 64 bytes).
    pub model: String,
    /// Artifact epoch (bumped by `pdq repack`; ≥ 1).
    pub epoch: u64,
    /// The model's task (drives calibration data for repack).
    pub task: Task,
    /// Pack wall-clock, seconds since the Unix epoch.
    pub created_unix: u64,
    /// Nominal input shape.
    pub input_shape: Shape,
    /// Declared output shapes (validated against shape inference).
    pub output_shapes: Vec<Shape>,
    /// PDQ sampling stride γ.
    pub gamma: usize,
    /// Calibration coverage quantile.
    pub coverage: f32,
    /// Input grid scale (the executors' fixed `[0, 1]` input grid).
    pub input_scale: f32,
    /// Input grid zero point.
    pub input_zero: i32,
    /// Number of calibration images used.
    pub calib_images: usize,
    /// Calibration provenance (`"task-calib"`, `"repack"`, caller-set).
    pub calib_source: String,
    /// Graph nodes, topological (node 0 is the input).
    pub nodes: Vec<NodeSpec>,
    /// Output node ids (explicit and non-empty in v1).
    pub outputs: Vec<usize>,
    /// Calibration tables, one per quantizable node, in node order.
    pub calib: Vec<CalibSpec>,
    /// Weight-scale granularity of the int8 lowering.
    pub weight_gran: Granularity,
    /// Int8 lowering metadata, one per quantizable node, in node order.
    pub int8_layers: Vec<Int8LayerSpec>,
    /// Canonical variant wire names this artifact serves (the 13 cells).
    pub variants: Vec<String>,
    /// Payload section checksum table, in payload order.
    pub sections: Vec<SectionEntry>,
}

fn bad(ctx: &str, why: &str) -> ArtifactError {
    ArtifactError::BadManifest(format!("{ctx}: {why}"))
}

fn bad_graph(ctx: &str, why: &str) -> ArtifactError {
    ArtifactError::BadGraph(format!("{ctx}: {why}"))
}

fn field<'a>(obj: &'a Json, key: &str, ctx: &str) -> Result<&'a Json, ArtifactError> {
    obj.get(key).ok_or_else(|| bad(ctx, &format!("missing field {key:?}")))
}

fn str_field<'a>(obj: &'a Json, key: &str, ctx: &str) -> Result<&'a str, ArtifactError> {
    let s = field(obj, key, ctx)?
        .as_str()
        .ok_or_else(|| bad(ctx, &format!("field {key:?} must be a string")))?;
    if s.len() > MAX_STR {
        return Err(bad(ctx, &format!("field {key:?} longer than {MAX_STR} bytes")));
    }
    Ok(s)
}

fn arr_field<'a>(obj: &'a Json, key: &str, ctx: &str) -> Result<&'a [Json], ArtifactError> {
    field(obj, key, ctx)?
        .as_arr()
        .ok_or_else(|| bad(ctx, &format!("field {key:?} must be an array")))
}

/// Strict integer read: the value must be a finite, integral JSON number
/// inside `[lo, hi]`. (`Json::as_usize` truncates fractions and saturates
/// negatives — unusable on untrusted bytes.)
fn int_in(j: &Json, lo: i64, hi: i64, ctx: &str) -> Result<i64, ArtifactError> {
    let n = j.as_f64().ok_or_else(|| bad(ctx, "expected a number"))?;
    if !n.is_finite() || n != n.trunc() || n < lo as f64 || n > hi as f64 {
        return Err(bad(ctx, &format!("expected an integer in [{lo}, {hi}], got {n}")));
    }
    Ok(n as i64)
}

fn usize_in(j: &Json, lo: i64, hi: i64, ctx: &str) -> Result<usize, ArtifactError> {
    Ok(int_in(j, lo, hi, ctx)? as usize)
}

fn u64_field(obj: &Json, key: &str, ctx: &str) -> Result<u64, ArtifactError> {
    Ok(int_in(field(obj, key, ctx)?, 0, i64::MAX, ctx)? as u64)
}

fn usize_arr(j: &Json, max_len: usize, max_val: usize, ctx: &str) -> Result<Vec<usize>, ArtifactError> {
    let arr = j.as_arr().ok_or_else(|| bad(ctx, "expected an array"))?;
    if arr.len() > max_len {
        return Err(bad(ctx, &format!("array longer than {max_len}")));
    }
    arr.iter().map(|v| usize_in(v, 0, max_val as i64, ctx)).collect()
}

/// An `f32` stored as its exact bit pattern (`u32` integer).
fn f32_bits(j: &Json, ctx: &str) -> Result<f32, ArtifactError> {
    Ok(f32::from_bits(int_in(j, 0, u32::MAX as i64, ctx)? as u32))
}

fn jf32(v: f32) -> Json {
    Json::Num(v.to_bits() as f64)
}

fn align_up(x: usize, ctx: &str) -> Result<usize, ArtifactError> {
    x.checked_add(ALIGN - 1)
        .map(|v| v / ALIGN * ALIGN)
        .ok_or_else(|| bad(ctx, "section offset overflow"))
}

/// Per-dim + element-count caps; returns the checked element count.
fn check_dims(dims: &[usize], ctx: &str) -> Result<u64, ArtifactError> {
    if dims.is_empty() {
        return Err(bad_graph(ctx, "rank-0 shape"));
    }
    let mut numel = 1u64;
    for &d in dims {
        if d == 0 || d > MAX_DIM {
            return Err(bad_graph(ctx, &format!("dimension {d} outside 1..={MAX_DIM}")));
        }
        numel = numel
            .checked_mul(d as u64)
            .filter(|&n| n <= MAX_TENSOR_ELEMS as u64)
            .ok_or_else(|| bad_graph(ctx, &format!("element count exceeds {MAX_TENSOR_ELEMS}")))?;
    }
    Ok(numel)
}

/// The canonical 13-cell serving menu of every v1 artifact, in
/// [`crate::engine::standard_menu`] order: fp32, the three fake-quant
/// modes (per-tensor activations), then int8 `{static, dynamic, ours}`
/// at rungs 8/4/2 sharing one weight copy at the given granularity.
pub fn menu_specs(weight_gran: Granularity) -> Vec<VariantSpec> {
    let mut out = vec![VariantSpec::Fp32];
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        out.push(VariantSpec::FakeQuant { mode, gran: Granularity::PerTensor });
    }
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        for bits in [8u32, 4, 2] {
            out.push(VariantSpec::Int8 { mode, weight_gran, bits });
        }
    }
    out
}

impl Manifest {
    /// Ids of quantizable nodes, in order (the payload-backed layers).
    pub fn quantizable(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.is_quantizable())
            .map(|(i, _)| i)
            .collect()
    }

    /// The wire names this manifest must declare, in canonical order.
    pub fn expected_wires(&self) -> Vec<String> {
        menu_specs(self.weight_gran).iter().map(|s| s.wire()).collect()
    }

    /// Checked shape inference over the declared graph. Mirrors
    /// [`crate::nn::memory::infer_shapes`] exactly, but with `u64`
    /// arithmetic and caps so a hostile manifest cannot overflow,
    /// underflow, or amplify memory. Also enforces topology: node 0 is
    /// the single input, operands reference earlier nodes only.
    pub fn infer_shapes(&self) -> Result<Vec<Shape>, ArtifactError> {
        if self.nodes.is_empty() {
            return Err(bad_graph("graph", "no nodes"));
        }
        if self.nodes.len() > MAX_NODES {
            return Err(bad_graph("graph", &format!("more than {MAX_NODES} nodes")));
        }
        check_dims(self.input_shape.dims(), "input_shape")?;
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let ctx = format!("graph.nodes[{i}]");
            if (i == 0) != matches!(node, NodeSpec::Input) {
                return Err(bad_graph(&ctx, "node 0 must be the single input"));
            }
            for &inp in &node.inputs() {
                if inp >= i {
                    return Err(bad_graph(&ctx, &format!("input {inp} is not an earlier node")));
                }
            }
            let dims = match node {
                NodeSpec::Input => self.input_shape.dims().to_vec(),
                NodeSpec::Conv { input, wshape, stride, pad }
                | NodeSpec::DwConv { input, wshape, stride, pad } => {
                    let s = &shapes[*input];
                    if s.len() != 3 {
                        return Err(bad_graph(&ctx, "conv input must be rank-3 HWC"));
                    }
                    check_dims(wshape, &ctx)?;
                    let dw = matches!(node, NodeSpec::DwConv { .. });
                    let (kh, kw, in_ch, out_ch) = if dw {
                        if wshape.len() != 3 {
                            return Err(bad_graph(&ctx, "dwconv weight must be [C, kh, kw]"));
                        }
                        (wshape[1], wshape[2], wshape[0], wshape[0])
                    } else {
                        if wshape.len() != 4 {
                            return Err(bad_graph(&ctx, "conv weight must be OHWI"));
                        }
                        (wshape[1], wshape[2], wshape[3], wshape[0])
                    };
                    if in_ch != s[2] {
                        return Err(bad_graph(&ctx, "kernel input channels != activation channels"));
                    }
                    if kh > MAX_GEOM || kw > MAX_GEOM || *stride > MAX_GEOM || *pad > MAX_GEOM {
                        return Err(bad_graph(&ctx, &format!("geometry exceeds {MAX_GEOM}")));
                    }
                    // h, w ≤ MAX_DIM and pad ≤ MAX_GEOM: no usize overflow.
                    let (padded_h, padded_w) = (s[0] + 2 * pad, s[1] + 2 * pad);
                    if kh > padded_h || kw > padded_w {
                        return Err(bad_graph(&ctx, "kernel larger than padded input"));
                    }
                    vec![(padded_h - kh) / stride + 1, (padded_w - kw) / stride + 1, out_ch]
                }
                NodeSpec::Linear { input, wshape } => {
                    let numel = check_dims(&shapes[*input], &ctx)?;
                    check_dims(wshape, &ctx)?;
                    if wshape.len() != 2 {
                        return Err(bad_graph(&ctx, "linear weight must be [h, d]"));
                    }
                    if wshape[1] as u64 != numel {
                        return Err(bad_graph(&ctx, "linear width != input element count"));
                    }
                    vec![wshape[0]]
                }
                NodeSpec::Relu { input } | NodeSpec::Relu6 { input } => shapes[*input].clone(),
                NodeSpec::MaxPool { input, k, stride } => {
                    let s = &shapes[*input];
                    if s.len() != 3 {
                        return Err(bad_graph(&ctx, "maxpool input must be rank-3 HWC"));
                    }
                    if *k > s[0] || *k > s[1] {
                        return Err(bad_graph(&ctx, "pool window larger than input"));
                    }
                    vec![(s[0] - k) / stride + 1, (s[1] - k) / stride + 1, s[2]]
                }
                NodeSpec::Gap { input } => vec![*shapes[*input].last().unwrap()],
                NodeSpec::Flatten { input } => {
                    vec![check_dims(&shapes[*input], &ctx)? as usize]
                }
                NodeSpec::Add { a, b } => {
                    if shapes[*a] != shapes[*b] {
                        return Err(bad_graph(&ctx, "add operands have different shapes"));
                    }
                    shapes[*a].clone()
                }
            };
            check_dims(&dims, &ctx)?;
            shapes.push(dims);
        }
        Ok(shapes.into_iter().map(|d| Shape::new(&d)).collect())
    }

    /// The canonical payload layout implied by the graph: per quantizable
    /// node `i`, sections `w{i}` `b{i}` `k{i}` (`rs{i}` linear-only)
    /// `bq{i}` `rq{i}`, each [`ALIGN`]-aligned, in node order. Returned
    /// entries carry `crc: 0` — the declared table must match everything
    /// *except* the CRC, which only the payload bytes can witness.
    pub fn expected_layout(&self) -> Result<Vec<SectionEntry>, ArtifactError> {
        let mut out: Vec<SectionEntry> = Vec::new();
        let mut off = 0usize;
        let ctx = "sections";
        let mut push = |name: String, dtype: SectionDtype, len: usize| -> Result<(), ArtifactError> {
            out.push(SectionEntry { name, off, len, crc: 0, dtype });
            let end = off.checked_add(len).ok_or_else(|| bad(ctx, "section length overflow"))?;
            off = align_up(end, ctx)?;
            Ok(())
        };
        for (i, node) in self.nodes.iter().enumerate() {
            let Some(wshape) = node.wshape() else { continue };
            let wnumel = check_dims(wshape, ctx)? as usize;
            let channels = wshape[0];
            let n_mult = match self.weight_gran {
                Granularity::PerTensor => 1,
                Granularity::PerChannel => channels,
            };
            push(format!("w{i}"), SectionDtype::F32, wnumel * 4)?;
            push(format!("b{i}"), SectionDtype::F32, channels * 4)?;
            push(format!("k{i}"), SectionDtype::I8, wnumel)?;
            if matches!(node, NodeSpec::Linear { .. }) {
                push(format!("rs{i}"), SectionDtype::I32, channels * 4)?;
            }
            push(format!("bq{i}"), SectionDtype::I32, channels * 4)?;
            push(format!("rq{i}"), SectionDtype::I32, n_mult * 2 * 4)?;
        }
        if out.len() > MAX_SECTIONS {
            return Err(bad(ctx, &format!("more than {MAX_SECTIONS} sections")));
        }
        Ok(out)
    }

    /// Exact payload byte length the canonical layout requires.
    pub fn expected_payload_len(&self) -> Result<usize, ArtifactError> {
        Ok(self.expected_layout()?.last().map(|e| e.off + e.len).unwrap_or(0))
    }

    /// Full structural validation: identity and knobs, graph topology +
    /// checked shape inference, declared output shapes, calibration and
    /// int8 tables (counts, finiteness, grid sanity), the canonical
    /// variant list, and the section table against the canonical layout
    /// and `payload_len`. Returns the inferred per-node shapes.
    ///
    /// After this passes, the *only* remaining trust gap is payload byte
    /// content — covered by [`Manifest::verify_sections`] (CRC) and the
    /// loader's semantic cross-checks.
    pub fn validate(&self, payload_len: usize) -> Result<Vec<Shape>, ArtifactError> {
        VariantKey::parse_wire(&format!("{}|fp32", self.model))
            .map_err(|e| bad("model", &e))?;
        if self.epoch == 0 {
            return Err(bad("epoch", "must be >= 1"));
        }
        if self.gamma == 0 || self.gamma > MAX_GAMMA {
            return Err(bad("knobs.gamma", &format!("outside 1..={MAX_GAMMA}")));
        }
        if !self.coverage.is_finite() || self.coverage <= 0.0 || self.coverage >= 1.0 {
            return Err(bad("knobs.coverage", "must be finite in (0, 1)"));
        }
        if !(self.input_scale.is_finite() && self.input_scale > 0.0) {
            return Err(bad("input_q.scale", "must be finite and positive"));
        }
        if (self.input_zero as i64).abs() > MAX_ZP {
            return Err(bad("input_q.zero", &format!("|zero| exceeds {MAX_ZP}")));
        }
        if self.calib_images == 0 || self.calib_images > 1 << 20 {
            return Err(bad("calibration.images", "outside 1..=1048576"));
        }
        let shapes = self.infer_shapes()?;

        if self.outputs.is_empty() {
            return Err(bad_graph("graph.outputs", "empty"));
        }
        if self.outputs.len() != self.output_shapes.len() {
            return Err(bad_graph("output_shapes", "count != graph.outputs count"));
        }
        for (i, &o) in self.outputs.iter().enumerate() {
            if o >= self.nodes.len() {
                return Err(bad_graph("graph.outputs", &format!("output {o} out of range")));
            }
            if self.output_shapes[i] != shapes[o] {
                return Err(bad_graph(
                    "output_shapes",
                    &format!("declared {:?} != inferred {:?}", self.output_shapes[i], shapes[o]),
                ));
            }
        }

        let q = self.quantizable();
        if self.calib.len() != q.len() || self.int8_layers.len() != q.len() {
            return Err(ArtifactError::BadVariant(format!(
                "calib/int8 tables cover {}/{} layers, graph has {} quantizable",
                self.calib.len(),
                self.int8_layers.len(),
                q.len()
            )));
        }
        for (ci, (&idx, c)) in q.iter().zip(&self.calib).enumerate() {
            let ctx = format!("calib[{ci}]");
            if c.node != idx {
                return Err(ArtifactError::BadVariant(format!("{ctx}: node {} != {idx}", c.node)));
            }
            if c.ranges.len() != 1 {
                return Err(ArtifactError::BadVariant(format!(
                    "{ctx}: v1 activations are per-tensor (one range), got {}",
                    c.ranges.len()
                )));
            }
            for &(lo, hi) in &c.ranges {
                if !(lo.is_finite() && hi.is_finite() && lo <= hi) {
                    return Err(ArtifactError::BadVariant(format!("{ctx}: bad range [{lo}, {hi}]")));
                }
            }
            if !(c.interval.alpha.is_finite() && c.interval.beta.is_finite()) {
                return Err(ArtifactError::BadVariant(format!("{ctx}: non-finite interval")));
            }
        }
        for (li, (&idx, l)) in q.iter().zip(&self.int8_layers).enumerate() {
            let ctx = format!("int8.layers[{li}]");
            if l.node != idx {
                return Err(ArtifactError::BadVariant(format!("{ctx}: node {} != {idx}", l.node)));
            }
            let channels = self.nodes[idx].wshape().map(|w| w[0]).unwrap_or(0);
            let want_sw = match self.weight_gran {
                Granularity::PerTensor => 1,
                Granularity::PerChannel => channels,
            };
            if l.s_w.len() != want_sw {
                return Err(ArtifactError::BadVariant(format!(
                    "{ctx}: {} weight scales, want {want_sw}",
                    l.s_w.len()
                )));
            }
            if !l.s_w.iter().all(|s| s.is_finite() && *s > 0.0) {
                return Err(ArtifactError::BadVariant(format!("{ctx}: weight scales must be finite > 0")));
            }
            let finite = [l.mu_w, l.bias_mu, l.interval.alpha, l.interval.beta];
            if !finite.iter().all(|v| v.is_finite()) {
                return Err(ArtifactError::BadVariant(format!("{ctx}: non-finite surrogate stats")));
            }
            if !(l.var_w.is_finite() && l.var_w >= 0.0 && l.bias_var.is_finite() && l.bias_var >= 0.0)
            {
                return Err(ArtifactError::BadVariant(format!("{ctx}: variances must be finite >= 0")));
            }
            let s = &l.static_spec;
            if !(s.out_scale.is_finite() && s.out_scale > 0.0) {
                return Err(ArtifactError::BadVariant(format!("{ctx}: static out_scale must be finite > 0")));
            }
            if (s.out_zero as i64).abs() > MAX_ZP || s.offset != s.out_zero {
                return Err(ArtifactError::BadVariant(format!(
                    "{ctx}: static zero/offset out of range or inconsistent"
                )));
            }
            if !(-128..=127).contains(&s.act_min)
                || !(-128..=127).contains(&s.act_max)
                || s.act_min > s.act_max
            {
                return Err(ArtifactError::BadVariant(format!("{ctx}: bad activation clamp window")));
            }
        }

        let wires = self.expected_wires();
        if self.variants != wires {
            return Err(ArtifactError::BadVariant(format!(
                "variant list drift: declared {:?}, canonical {:?}",
                self.variants, wires
            )));
        }

        let layout = self.expected_layout()?;
        if self.sections.len() != layout.len() {
            return Err(bad(
                "sections",
                &format!("{} entries, canonical layout has {}", self.sections.len(), layout.len()),
            ));
        }
        for (got, want) in self.sections.iter().zip(&layout) {
            if got.name != want.name
                || got.off != want.off
                || got.len != want.len
                || got.dtype != want.dtype
            {
                return Err(bad(
                    "sections",
                    &format!(
                        "entry {:?} (off {}, len {}, {:?}) != canonical {:?} (off {}, len {}, {:?})",
                        got.name, got.off, got.len, got.dtype, want.name, want.off, want.len,
                        want.dtype
                    ),
                ));
            }
        }
        let want_len = layout.last().map(|e| e.off + e.len).unwrap_or(0);
        if payload_len != want_len {
            return Err(ArtifactError::Truncated { need: want_len, have: payload_len });
        }
        Ok(shapes)
    }

    /// Verify every section CRC against the payload bytes.
    pub fn verify_sections(&self, payload: &[u8]) -> Result<(), ArtifactError> {
        for e in &self.sections {
            let end = e
                .off
                .checked_add(e.len)
                .ok_or(ArtifactError::Truncated { need: usize::MAX, have: payload.len() })?;
            if end > payload.len() {
                return Err(ArtifactError::Truncated { need: end, have: payload.len() });
            }
            if crc32(&payload[e.off..end]) != e.crc {
                return Err(ArtifactError::ChecksumMismatch { section: e.name.clone() });
            }
        }
        Ok(())
    }

    /// Look up a section entry by name.
    pub fn section(&self, name: &str) -> Option<&SectionEntry> {
        self.sections.iter().find(|e| e.name == name)
    }

    /// Bounds-checked byte view of a named section.
    pub fn section_bytes<'a>(
        &self,
        payload: &'a [u8],
        name: &str,
    ) -> Result<&'a [u8], ArtifactError> {
        let e = self
            .section(name)
            .ok_or_else(|| bad("sections", &format!("missing section {name:?}")))?;
        let end = e
            .off
            .checked_add(e.len)
            .filter(|&end| end <= payload.len())
            .ok_or(ArtifactError::Truncated { need: e.off.saturating_add(e.len), have: payload.len() })?;
        Ok(&payload[e.off..end])
    }

    /// Serialize to the manifest JSON document.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", SCHEMA)
            .set("model", self.model.as_str())
            .set("epoch", self.epoch)
            .set("task", self.task.name())
            .set("created_unix", self.created_unix)
            .set("input_shape", self.input_shape.dims().to_vec());
        j.set(
            "output_shapes",
            Json::Arr(self.output_shapes.iter().map(|s| Json::from(s.dims().to_vec())).collect()),
        );
        let mut knobs = Json::obj();
        knobs.set("gamma", self.gamma).set("coverage", jf32(self.coverage));
        j.set("knobs", knobs);
        let mut input_q = Json::obj();
        input_q.set("scale", jf32(self.input_scale)).set("zero", self.input_zero as i64);
        j.set("input_q", input_q);
        let mut calibration = Json::obj();
        calibration.set("images", self.calib_images).set("source", self.calib_source.as_str());
        j.set("calibration", calibration);
        let mut graph = Json::obj();
        graph.set("nodes", Json::Arr(self.nodes.iter().map(|n| n.to_json()).collect()));
        graph.set("outputs", self.outputs.clone());
        j.set("graph", graph);
        j.set(
            "calib",
            Json::Arr(
                self.calib
                    .iter()
                    .map(|c| {
                        let mut cj = Json::obj();
                        cj.set("node", c.node)
                            .set(
                                "interval",
                                Json::Arr(vec![jf32(c.interval.alpha), jf32(c.interval.beta)]),
                            )
                            .set(
                                "ranges",
                                Json::Arr(
                                    c.ranges
                                        .iter()
                                        .map(|&(lo, hi)| Json::Arr(vec![jf32(lo), jf32(hi)]))
                                        .collect(),
                                ),
                            );
                        cj
                    })
                    .collect(),
            ),
        );
        let mut int8 = Json::obj();
        int8.set(
            "weight_gran",
            match self.weight_gran {
                Granularity::PerTensor => "t",
                Granularity::PerChannel => "c",
            },
        );
        int8.set(
            "layers",
            Json::Arr(
                self.int8_layers
                    .iter()
                    .map(|l| {
                        let mut lj = Json::obj();
                        lj.set("node", l.node)
                            .set("s_w", Json::Arr(l.s_w.iter().map(|&s| jf32(s)).collect()))
                            .set("mu_w", jf32(l.mu_w))
                            .set("var_w", jf32(l.var_w))
                            .set("bias_mu", jf32(l.bias_mu))
                            .set("bias_var", jf32(l.bias_var))
                            .set(
                                "interval",
                                Json::Arr(vec![jf32(l.interval.alpha), jf32(l.interval.beta)]),
                            );
                        let s = &l.static_spec;
                        let mut sj = Json::obj();
                        sj.set("out_scale", jf32(s.out_scale))
                            .set("out_zero", s.out_zero as i64)
                            .set("offset", s.offset as i64)
                            .set("act_min", s.act_min as i64)
                            .set("act_max", s.act_max as i64);
                        lj.set("static", sj);
                        lj
                    })
                    .collect(),
            ),
        );
        j.set("int8", int8);
        j.set("variants", self.variants.clone());
        j.set(
            "sections",
            Json::Arr(
                self.sections
                    .iter()
                    .map(|e| {
                        let mut ej = Json::obj();
                        ej.set("name", e.name.as_str())
                            .set("off", e.off)
                            .set("len", e.len)
                            .set("crc", e.crc as u64)
                            .set("dtype", e.dtype.wire());
                        ej
                    })
                    .collect(),
            ),
        );
        j
    }

    /// Pretty-printed manifest text (what goes in the file).
    pub fn to_json_text(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse a manifest document from text.
    pub fn parse(text: &str) -> Result<Manifest, ArtifactError> {
        let json = Json::parse(text).map_err(ArtifactError::BadManifest)?;
        Manifest::from_json(&json)
    }

    /// Build from a parsed JSON value. Strict: missing/mistyped/out-of-
    /// range fields are typed errors. Structural consistency is
    /// [`Manifest::validate`]'s job; this only guarantees well-formed,
    /// capped fields.
    pub fn from_json(json: &Json) -> Result<Manifest, ArtifactError> {
        let ctx = "manifest";
        let schema = str_field(json, "schema", ctx)?;
        if schema != SCHEMA {
            return Err(ArtifactError::SchemaMismatch { found: schema.to_string() });
        }
        let model = str_field(json, "model", ctx)?.to_string();
        let epoch = u64_field(json, "epoch", ctx)?;
        let task: Task =
            str_field(json, "task", ctx)?.parse().map_err(|e: String| bad("task", &e))?;
        let created_unix = u64_field(json, "created_unix", ctx)?;
        let input_shape =
            Shape::new(&usize_arr(field(json, "input_shape", ctx)?, 8, MAX_DIM, "input_shape")?);
        let output_shapes = field(json, "output_shapes", ctx)?
            .as_arr()
            .ok_or_else(|| bad(ctx, "output_shapes must be an array"))?
            .iter()
            .map(|s| Ok(Shape::new(&usize_arr(s, 8, MAX_DIM, "output_shapes")?)))
            .collect::<Result<Vec<_>, ArtifactError>>()?;
        if output_shapes.len() > 64 {
            return Err(bad(ctx, "more than 64 output shapes"));
        }

        let knobs = field(json, "knobs", ctx)?;
        let gamma = usize_in(field(knobs, "gamma", "knobs")?, 0, MAX_GAMMA as i64, "knobs.gamma")?;
        let coverage = f32_bits(field(knobs, "coverage", "knobs")?, "knobs.coverage")?;
        let input_q = field(json, "input_q", ctx)?;
        let input_scale = f32_bits(field(input_q, "scale", "input_q")?, "input_q.scale")?;
        let input_zero =
            int_in(field(input_q, "zero", "input_q")?, -MAX_ZP, MAX_ZP, "input_q.zero")? as i32;
        let calibration = field(json, "calibration", ctx)?;
        let calib_images =
            usize_in(field(calibration, "images", "calibration")?, 0, 1 << 20, "calibration.images")?;
        let calib_source = str_field(calibration, "source", "calibration")?.to_string();

        let graph = field(json, "graph", ctx)?;
        let node_arr = arr_field(graph, "nodes", "graph")?;
        if node_arr.len() > MAX_NODES {
            return Err(bad("graph.nodes", &format!("more than {MAX_NODES} nodes")));
        }
        let nodes = node_arr
            .iter()
            .enumerate()
            .map(|(i, n)| NodeSpec::from_json(n, i))
            .collect::<Result<Vec<_>, _>>()?;
        let outputs = usize_arr(field(graph, "outputs", "graph")?, 64, MAX_NODES, "graph.outputs")?;

        let calib = arr_field(json, "calib", ctx)?
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let cctx = format!("calib[{i}]");
                let node = usize_in(field(c, "node", &cctx)?, 0, MAX_NODES as i64, &cctx)?;
                let interval = interval_from_json(field(c, "interval", &cctx)?, &cctx)?;
                let ranges = arr_field(c, "ranges", &cctx)?
                    .iter()
                    .map(|r| {
                        let arr = r.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                            bad(&cctx, "each range must be a [lo, hi] pair")
                        })?;
                        Ok((f32_bits(&arr[0], &cctx)?, f32_bits(&arr[1], &cctx)?))
                    })
                    .collect::<Result<Vec<_>, ArtifactError>>()?;
                if ranges.len() > MAX_DIM {
                    return Err(bad(&cctx, "too many ranges"));
                }
                Ok(CalibSpec { node, interval, ranges })
            })
            .collect::<Result<Vec<_>, ArtifactError>>()?;
        if calib.len() > MAX_NODES {
            return Err(bad("calib", &format!("more than {MAX_NODES} entries")));
        }

        let int8 = field(json, "int8", ctx)?;
        let weight_gran = match str_field(int8, "weight_gran", "int8")? {
            "t" => Granularity::PerTensor,
            "c" => Granularity::PerChannel,
            other => return Err(bad("int8.weight_gran", &format!("unknown granularity {other:?}"))),
        };
        let layer_arr = arr_field(int8, "layers", "int8")?;
        if layer_arr.len() > MAX_NODES {
            return Err(bad("int8.layers", &format!("more than {MAX_NODES} entries")));
        }
        let int8_layers = layer_arr
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let lctx = format!("int8.layers[{i}]");
                let node = usize_in(field(l, "node", &lctx)?, 0, MAX_NODES as i64, &lctx)?;
                let sw_arr = arr_field(l, "s_w", &lctx)?;
                if sw_arr.len() > MAX_DIM {
                    return Err(bad(&lctx, "too many weight scales"));
                }
                let s_w = sw_arr
                    .iter()
                    .map(|s| f32_bits(s, &lctx))
                    .collect::<Result<Vec<_>, _>>()?;
                let st = field(l, "static", &lctx)?;
                Ok(Int8LayerSpec {
                    node,
                    s_w,
                    mu_w: f32_bits(field(l, "mu_w", &lctx)?, &lctx)?,
                    var_w: f32_bits(field(l, "var_w", &lctx)?, &lctx)?,
                    bias_mu: f32_bits(field(l, "bias_mu", &lctx)?, &lctx)?,
                    bias_var: f32_bits(field(l, "bias_var", &lctx)?, &lctx)?,
                    interval: interval_from_json(field(l, "interval", &lctx)?, &lctx)?,
                    static_spec: StaticSpec {
                        out_scale: f32_bits(field(st, "out_scale", &lctx)?, &lctx)?,
                        out_zero: int_in(field(st, "out_zero", &lctx)?, -MAX_ZP, MAX_ZP, &lctx)?
                            as i32,
                        offset: int_in(field(st, "offset", &lctx)?, -MAX_ZP, MAX_ZP, &lctx)? as i32,
                        act_min: int_in(field(st, "act_min", &lctx)?, -128, 127, &lctx)? as i32,
                        act_max: int_in(field(st, "act_max", &lctx)?, -128, 127, &lctx)? as i32,
                    },
                })
            })
            .collect::<Result<Vec<_>, ArtifactError>>()?;

        let variant_arr = arr_field(json, "variants", ctx)?;
        if variant_arr.len() > 64 {
            return Err(bad("variants", "more than 64 variants"));
        }
        let variants = variant_arr
            .iter()
            .map(|v| {
                v.as_str()
                    .filter(|s| s.len() <= MAX_STR)
                    .map(str::to_string)
                    .ok_or_else(|| bad("variants", "each variant must be a short string"))
            })
            .collect::<Result<Vec<_>, _>>()?;

        let section_arr = arr_field(json, "sections", ctx)?;
        if section_arr.len() > MAX_SECTIONS {
            return Err(bad("sections", &format!("more than {MAX_SECTIONS} sections")));
        }
        let sections = section_arr
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let sctx = format!("sections[{i}]");
                let dtype_s = str_field(e, "dtype", &sctx)?;
                Ok(SectionEntry {
                    name: str_field(e, "name", &sctx)?.to_string(),
                    off: usize_in(field(e, "off", &sctx)?, 0, 1 << 40, &sctx)?,
                    len: usize_in(field(e, "len", &sctx)?, 0, 1 << 40, &sctx)?,
                    crc: int_in(field(e, "crc", &sctx)?, 0, u32::MAX as i64, &sctx)? as u32,
                    dtype: SectionDtype::parse(dtype_s)
                        .ok_or_else(|| bad(&sctx, &format!("unknown dtype {dtype_s:?}")))?,
                })
            })
            .collect::<Result<Vec<_>, ArtifactError>>()?;

        Ok(Manifest {
            model,
            epoch,
            task,
            created_unix,
            input_shape,
            output_shapes,
            gamma,
            coverage,
            input_scale,
            input_zero,
            calib_images,
            calib_source,
            nodes,
            outputs,
            calib,
            weight_gran,
            int8_layers,
            variants,
            sections,
        })
    }
}

fn interval_from_json(j: &Json, ctx: &str) -> Result<IntervalSpec, ArtifactError> {
    let arr = j
        .as_arr()
        .filter(|a| a.len() == 2)
        .ok_or_else(|| bad(ctx, "interval must be an [alpha, beta] pair"))?;
    Ok(IntervalSpec { alpha: f32_bits(&arr[0], ctx)?, beta: f32_bits(&arr[1], ctx)? })
}
