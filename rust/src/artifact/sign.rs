//! Keyed-hash artifact signing: the tamper wall on top of the CRC wall.
//!
//! The per-section CRC32s detect *corruption* — bit rot, short writes,
//! text-mode mangling — but an attacker who can rewrite artifact bytes can
//! rewrite the CRCs to match. For untrusted artifact stores the file needs
//! a secret-keyed check: `pdq pack --sign-key` appends an HMAC-SHA-256
//! trailer over the complete artifact, and `pdq inspect --verify-key` /
//! [`crate::artifact::ArtifactEngine`] recompute it before trusting a
//! byte of the payload.
//!
//! Trailer layout, appended after the artifact's payload:
//!
//! ```text
//! ┌───────────────────────┬──────────────────────────────┐
//! │ magic "PDQSIG1\n" 8 B │ HMAC-SHA-256 tag (32 bytes)  │
//! └───────────────────────┴──────────────────────────────┘
//! ```
//!
//! The trailer sits *outside* the signed region (the tag covers every
//! byte before the trailer), and outside the `pdq-artifact-v1` structure:
//! [`split_trailer`] strips it before `split_artifact` ever sees the
//! bytes, so signed artifacts remain loadable by readers that know
//! nothing about signing. SHA-256 is hand-rolled here (std-only crate,
//! same rationale as the `crc32` module) and pinned to the NIST and
//! RFC 4231 test vectors below.

use super::ArtifactError;

/// Signature trailer magic (8 bytes; the newline breaks text-mode
/// mangling the same way the artifact magic does).
pub const SIG_MAGIC: [u8; 8] = *b"PDQSIG1\n";

/// Full trailer size: magic + 32-byte HMAC-SHA-256 tag.
pub const TRAILER_LEN: usize = SIG_MAGIC.len() + 32;

// ---------------------------------------------------------------------------
// SHA-256 (FIPS 180-4)
// ---------------------------------------------------------------------------

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
    0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
    0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
    0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
    0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
    0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
    0x5be0cd19,
];

fn compress(state: &mut [u32; 8], block: &[u8]) {
    debug_assert_eq!(block.len(), 64);
    let mut w = [0u32; 64];
    for (i, chunk) in block.chunks_exact(4).enumerate() {
        w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    for (s, v) in state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
        *s = s.wrapping_add(v);
    }
}

/// SHA-256 over one or more byte slices (concatenated), single shot.
/// Multiple slices avoid materializing `key_pad ‖ message` in the HMAC
/// inner pass — artifacts are tens of MB.
fn sha256_multi(parts: &[&[u8]]) -> [u8; 32] {
    let mut state = H0;
    let total: u64 = parts.iter().map(|p| p.len() as u64).sum();
    let mut block = [0u8; 64];
    let mut fill = 0usize;
    for part in parts {
        let mut rest: &[u8] = part;
        while !rest.is_empty() {
            let take = (64 - fill).min(rest.len());
            block[fill..fill + take].copy_from_slice(&rest[..take]);
            fill += take;
            rest = &rest[take..];
            if fill == 64 {
                compress(&mut state, &block);
                fill = 0;
            }
        }
    }
    // Padding: 0x80, zeros, 64-bit big-endian bit length.
    block[fill] = 0x80;
    for b in block.iter_mut().skip(fill + 1) {
        *b = 0;
    }
    if fill + 1 + 8 > 64 {
        compress(&mut state, &block);
        block = [0u8; 64];
    }
    block[56..64].copy_from_slice(&(total * 8).to_be_bytes());
    compress(&mut state, &block);
    let mut out = [0u8; 32];
    for (i, s) in state.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&s.to_be_bytes());
    }
    out
}

/// SHA-256 of one message.
pub fn sha256(msg: &[u8]) -> [u8; 32] {
    sha256_multi(&[msg])
}

/// HMAC-SHA-256 (RFC 2104): keys longer than the 64-byte block are
/// hashed first; shorter keys are zero-padded.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0u8; 64];
    let mut opad = [0u8; 64];
    for i in 0..64 {
        ipad[i] = k[i] ^ 0x36;
        opad[i] = k[i] ^ 0x5c;
    }
    let inner = sha256_multi(&[&ipad, msg]);
    sha256_multi(&[&opad, &inner])
}

/// Constant-time-ish tag comparison: XOR-accumulate every byte so the
/// comparison cost does not depend on the first mismatching position.
fn tags_equal(a: &[u8; 32], b: &[u8; 32]) -> bool {
    a.iter().zip(b.iter()).fold(0u8, |acc, (x, y)| acc | (x ^ y)) == 0
}

/// Append the signature trailer to a packed artifact in place.
pub fn sign_artifact(bytes: &mut Vec<u8>, key: &[u8]) {
    let tag = hmac_sha256(key, bytes);
    bytes.extend_from_slice(&SIG_MAGIC);
    bytes.extend_from_slice(&tag);
}

/// Split a (possibly signed) artifact into `(body, trailer_tag)`.
/// Returns the body unchanged and `None` when no well-formed trailer is
/// present — unsigned artifacts flow through untouched, and signed ones
/// become loadable by signature-unaware readers after the strip.
pub fn split_trailer(bytes: &[u8]) -> (&[u8], Option<[u8; 32]>) {
    if bytes.len() < TRAILER_LEN {
        return (bytes, None);
    }
    let at = bytes.len() - TRAILER_LEN;
    if bytes[at..at + SIG_MAGIC.len()] != SIG_MAGIC {
        return (bytes, None);
    }
    let mut tag = [0u8; 32];
    tag.copy_from_slice(&bytes[at + SIG_MAGIC.len()..]);
    (&bytes[..at], Some(tag))
}

/// Verify a signed artifact against `key`, returning the stripped body.
/// No trailer ⇒ [`ArtifactError::SignatureMissing`]; a tag that does not
/// match ⇒ [`ArtifactError::SignatureMismatch`].
pub fn verify_artifact<'a>(bytes: &'a [u8], key: &[u8]) -> Result<&'a [u8], ArtifactError> {
    let (body, tag) = split_trailer(bytes);
    let Some(tag) = tag else {
        return Err(ArtifactError::SignatureMissing);
    };
    let want = hmac_sha256(key, body);
    if !tags_equal(&tag, &want) {
        return Err(ArtifactError::SignatureMismatch);
    }
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    /// NIST FIPS 180-4 vectors (one-block, two-block, empty).
    #[test]
    fn sha256_nist_vectors() {
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // Exactly one block of padding boundary (55/56/64-byte messages).
        assert_eq!(
            hex(&sha256(&[0x61u8; 56])),
            hex(&sha256_multi(&[&[0x61u8; 28], &[0x61u8; 28]])),
            "multi-slice streaming must match single-shot"
        );
    }

    /// RFC 4231 HMAC-SHA-256 test cases 1, 2, and 7 (long key).
    #[test]
    fn hmac_rfc4231_vectors() {
        // Case 1: key = 20 × 0x0b, data = "Hi There".
        assert_eq!(
            hex(&hmac_sha256(&[0x0b; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        // Case 2: key = "Jefe", data = "what do ya want for nothing?".
        assert_eq!(
            hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // Case 7: 131-byte key (forces the hash-the-key path).
        assert_eq!(
            hex(&hmac_sha256(
                &[0xaa; 131],
                b"This is a test using a larger than block-size key and a larger than block-size data. The key needs to be hashed before being used by the HMAC algorithm."
            )),
            "9b09ffa71b942fcb27635fbcd5b0e944bfdc63644f0713938a7f51535c3a35e2"
        );
    }

    #[test]
    fn sign_verify_round_trip_and_tamper() {
        let mut art = b"PDQA1\nnot really an artifact but bytes all the same".to_vec();
        let body_len = art.len();
        sign_artifact(&mut art, b"secret-key");
        assert_eq!(art.len(), body_len + TRAILER_LEN);
        // Verify returns the stripped body.
        let body = verify_artifact(&art, b"secret-key").unwrap();
        assert_eq!(body.len(), body_len);
        // Wrong key: mismatch, not missing.
        assert_eq!(
            verify_artifact(&art, b"wrong-key").unwrap_err(),
            ArtifactError::SignatureMismatch
        );
        // One flipped bit anywhere in the body: mismatch.
        let mut bad = art.clone();
        bad[10] ^= 0x01;
        assert_eq!(
            verify_artifact(&bad, b"secret-key").unwrap_err(),
            ArtifactError::SignatureMismatch
        );
        // A flipped tag bit too.
        let mut bad = art.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x80;
        assert_eq!(
            verify_artifact(&bad, b"secret-key").unwrap_err(),
            ArtifactError::SignatureMismatch
        );
        // Unsigned bytes with a key: missing.
        assert_eq!(
            verify_artifact(b"PDQA1\nunsigned", b"secret-key").unwrap_err(),
            ArtifactError::SignatureMissing
        );
    }

    #[test]
    fn split_trailer_is_safe_on_short_and_unsigned_inputs() {
        for input in [&b""[..], b"x", b"PDQSIG1\n", &[0u8; 39]] {
            let (body, tag) = split_trailer(input);
            assert_eq!(body, input);
            assert!(tag.is_none());
        }
        // 40 bytes that are all trailer: empty body, present tag.
        let mut t = SIG_MAGIC.to_vec();
        t.extend_from_slice(&[7u8; 32]);
        let (body, tag) = split_trailer(&t);
        assert!(body.is_empty());
        assert_eq!(tag, Some([7u8; 32]));
    }
}
