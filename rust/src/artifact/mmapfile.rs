//! Read-only file backing: `mmap(2)` when available, plain read fallback.
//!
//! A mapped artifact lets N serve processes verify and load the same file
//! while sharing one copy of its pages in the page cache. The wrapper is
//! std-only: on Unix it calls `mmap`/`munmap` directly through their C ABI
//! (libc is already linked by std), everywhere else — and whenever the map
//! fails — it falls back to `fs::read`. Callers only ever see `&[u8]`.

use std::fs::File;
use std::ops::Deref;
use std::path::Path;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// Owned or mapped read-only bytes of an artifact file.
pub enum Backing {
    /// Heap copy (non-Unix, map failure, empty file, or in-memory bytes).
    Owned(Vec<u8>),
    /// A live `MAP_PRIVATE` read-only mapping; unmapped on drop.
    #[cfg(unix)]
    Mapped {
        /// Base address returned by `mmap`.
        ptr: *mut u8,
        /// Mapping length in bytes (the file length at open).
        len: usize,
    },
}

// SAFETY: the mapping is PROT_READ + MAP_PRIVATE and we never hand out a
// mutable view, so shared access across threads is plain shared-immutable
// memory. (A concurrent writer truncating the file could still SIGBUS any
// mmap user — inherent to mmap, documented on `open`.)
#[cfg(unix)]
unsafe impl Send for Backing {}
#[cfg(unix)]
unsafe impl Sync for Backing {}

impl Backing {
    /// Open `path` read-only, preferring a shared page-cache mapping.
    ///
    /// Falls back to a heap read if mapping is unsupported or fails.
    /// Note the usual mmap caveat: truncating the file while it is mapped
    /// can fault readers; artifacts are immutable by convention (repack
    /// writes a new file).
    pub fn open(path: &Path) -> std::io::Result<Backing> {
        let file = File::open(path)?;
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let len = file.metadata()?.len();
            if len > 0 && len <= usize::MAX as u64 {
                let len = len as usize;
                // SAFETY: fd is a valid open file descriptor for `len`
                // bytes; we request a fresh read-only private mapping at a
                // kernel-chosen address and check for MAP_FAILED.
                let ptr = unsafe {
                    sys::mmap(
                        std::ptr::null_mut(),
                        len,
                        sys::PROT_READ,
                        sys::MAP_PRIVATE,
                        file.as_raw_fd(),
                        0,
                    )
                };
                if ptr as isize != -1 && !ptr.is_null() {
                    return Ok(Backing::Mapped { ptr: ptr as *mut u8, len });
                }
            }
        }
        drop(file);
        Ok(Backing::Owned(std::fs::read(path)?))
    }

    /// Read `path` into an owned heap buffer (never maps).
    pub fn read(path: &Path) -> std::io::Result<Backing> {
        Ok(Backing::Owned(std::fs::read(path)?))
    }

    /// The file contents.
    pub fn bytes(&self) -> &[u8] {
        match self {
            Backing::Owned(v) => v,
            #[cfg(unix)]
            // SAFETY: ptr/len describe a live PROT_READ mapping owned by
            // `self`; the slice cannot outlive it.
            Backing::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
        }
    }

    /// True when backed by a live `mmap` (page-cache shared) rather than a
    /// private heap copy.
    pub fn is_mapped(&self) -> bool {
        match self {
            Backing::Owned(_) => false,
            #[cfg(unix)]
            Backing::Mapped { .. } => true,
        }
    }
}

impl Deref for Backing {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.bytes()
    }
}

impl Drop for Backing {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = self {
            // SAFETY: ptr/len came from a successful mmap and are unmapped
            // exactly once, here. Failure is ignorable (address space leak
            // at worst, and only on kernel misbehaviour).
            unsafe {
                let _ = sys::munmap(*ptr as *mut std::os::raw::c_void, *len);
            }
        }
    }
}

impl std::fmt::Debug for Backing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backing::Owned(v) => write!(f, "Backing::Owned({} bytes)", v.len()),
            #[cfg(unix)]
            Backing::Mapped { len, .. } => write!(f, "Backing::Mapped({len} bytes)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::Backing;

    #[test]
    fn mmap_and_read_agree() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("pdq_backing_test_{}.bin", std::process::id()));
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 7 + 3) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let mapped = Backing::open(&path).unwrap();
        let read = Backing::read(&path).unwrap();
        assert_eq!(&*mapped, &data[..]);
        assert_eq!(&*read, &data[..]);
        assert!(!read.is_mapped());
        #[cfg(unix)]
        assert!(mapped.is_mapped());
        drop(mapped);
        drop(read);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_is_owned() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("pdq_backing_empty_{}.bin", std::process::id()));
        std::fs::write(&path, b"").unwrap();
        let b = Backing::open(&path).unwrap();
        assert!(!b.is_mapped());
        assert!(b.is_empty());
        drop(b);
        std::fs::remove_file(&path).ok();
    }
}
