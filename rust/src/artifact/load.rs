//! Loading: `pdq-artifact-v1` bytes → a verified, ready-to-serve menu.
//!
//! Verification is layered so hostile bytes die as early and as cheaply
//! as possible: header structure (magic / length / manifest CRC), then
//! manifest parse + full structural validation ([`Manifest::validate`]),
//! then per-section payload CRCs, then *semantic* cross-checks — folded
//! biases, Q31 requant specs and FC row sums are recomputed from the
//! decoded tensors and compared bit-for-bit against the stored sections,
//! and the static output-grid chain is replayed node by node. A file that
//! passes all four layers builds the exact engines the in-process
//! [`crate::engine::standard_menu`] would have built. Every failure is a
//! typed [`ArtifactError`]; nothing here panics on file content.

use std::path::Path;
use std::sync::Arc;

use super::crc32::crc32;
use super::manifest::{Manifest, NodeSpec};
use super::mmapfile::Backing;
use super::payload::{decode_f32, decode_i32, decode_i8};
use super::sign::{split_trailer, verify_artifact};
use super::{ArtifactError, ALIGN, HEADER_LEN, MAGIC, MAX_MANIFEST_BYTES};
use crate::cmsis::pdq_wrappers::QOut;
use crate::cmsis::Requant;
use crate::engine::{Engine, FloatEngine, Int8Engine, QuantEngine, VariantKey, VariantSpec};
use crate::models::Model;
use crate::nn::graph::{Graph, NodeId};
use crate::nn::int8_exec::{
    add_grid, build_requant, fold_bias, Int8Executor, Int8Layer, Int8Node, Int8Op,
};
use crate::nn::quant_exec::QuantSettings;
use crate::nn::{QuantExecutor, QuantMode};
use crate::quant::{Granularity, QParams};
use crate::tensor::{ConvGeom, Shape, Tensor};

const MODES: [QuantMode; 3] = [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic];

fn bad_variant(why: impl Into<String>) -> ArtifactError {
    ArtifactError::BadVariant(why.into())
}

/// Split raw file bytes into a parsed manifest and the payload slice,
/// verifying the fixed header and the manifest CRC on the way. This is
/// the only place header structure is interpreted; `pack` reuses it to
/// self-verify and `inspect` to report.
pub(crate) fn split_artifact(bytes: &[u8]) -> Result<(Manifest, &[u8]), ArtifactError> {
    if bytes.len() < HEADER_LEN {
        return Err(ArtifactError::Truncated { need: HEADER_LEN, have: bytes.len() });
    }
    if bytes[..MAGIC.len()] != MAGIC {
        return Err(ArtifactError::BadMagic);
    }
    let mlen = u32::from_le_bytes([bytes[6], bytes[7], bytes[8], bytes[9]]) as usize;
    let mcrc = u32::from_le_bytes([bytes[10], bytes[11], bytes[12], bytes[13]]);
    if mlen > MAX_MANIFEST_BYTES {
        return Err(ArtifactError::ManifestTooLarge { len: mlen, max: MAX_MANIFEST_BYTES });
    }
    // No overflow: mlen ≤ 16 MiB.
    let need = HEADER_LEN + mlen;
    if bytes.len() < need {
        return Err(ArtifactError::Truncated { need, have: bytes.len() });
    }
    let mbytes = &bytes[HEADER_LEN..need];
    if crc32(mbytes) != mcrc {
        return Err(ArtifactError::ChecksumMismatch { section: "manifest".into() });
    }
    let text = std::str::from_utf8(mbytes)
        .map_err(|_| ArtifactError::BadManifest("manifest is not UTF-8".into()))?;
    let manifest = Manifest::parse(text)?;
    let payload_start = need + (ALIGN - need % ALIGN) % ALIGN;
    if bytes.len() < payload_start {
        return Err(ArtifactError::Truncated { need: payload_start, have: bytes.len() });
    }
    if bytes[need..payload_start].iter().any(|&b| b != 0) {
        return Err(ArtifactError::BadManifest("nonzero header padding".into()));
    }
    Ok((manifest, &bytes[payload_start..]))
}

/// Decoded payload pieces of one quantizable node, verified against
/// recomputation before any engine is built from them.
struct Pieces {
    kernel: Arc<Tensor<i8>>,
    bias_f: Vec<f32>,
    bias_q: Vec<i32>,
    w_row_sums: Vec<i32>,
    requant: Requant,
}

/// Decode the float weight/bias sections of node `idx` (finite-checked).
fn decode_params(
    manifest: &Manifest,
    payload: &[u8],
    idx: usize,
    wshape: &[usize],
) -> Result<(Tensor<f32>, Vec<f32>), ArtifactError> {
    let w = decode_f32(manifest.section_bytes(payload, &format!("w{idx}"))?);
    let b = decode_f32(manifest.section_bytes(payload, &format!("b{idx}"))?);
    if w.iter().chain(&b).any(|v| !v.is_finite()) {
        return Err(bad_variant(format!("node {idx}: non-finite float weight/bias")));
    }
    Ok((Tensor::from_vec(Shape::new(wshape), w), b))
}

/// Rebuild the f32 [`Graph`] from the validated manifest + payload. Every
/// builder assertion (rank, bias arity, geometry, topology) is implied by
/// [`Manifest::validate`], which ran first — this can only panic on a
/// loader bug, not on file content.
fn rebuild_graph(manifest: &Manifest, payload: &[u8]) -> Result<Graph, ArtifactError> {
    let mut g = Graph::new(manifest.input_shape.clone());
    for (idx, spec) in manifest.nodes.iter().enumerate() {
        match spec {
            NodeSpec::Input => {
                g.input();
            }
            NodeSpec::Conv { input, wshape, stride, pad } => {
                let (w, b) = decode_params(manifest, payload, idx, wshape)?;
                g.conv(NodeId(*input), w, b, ConvGeom::new(wshape[1], wshape[2], *stride, *pad));
            }
            NodeSpec::DwConv { input, wshape, stride, pad } => {
                let (w, b) = decode_params(manifest, payload, idx, wshape)?;
                g.dwconv(NodeId(*input), w, b, ConvGeom::new(wshape[1], wshape[2], *stride, *pad));
            }
            NodeSpec::Linear { input, wshape } => {
                let (w, b) = decode_params(manifest, payload, idx, wshape)?;
                g.linear(NodeId(*input), w, b);
            }
            NodeSpec::Relu { input } => {
                g.relu(NodeId(*input));
            }
            NodeSpec::Relu6 { input } => {
                g.relu6(NodeId(*input));
            }
            NodeSpec::MaxPool { input, k, stride } => {
                g.maxpool(NodeId(*input), *k, *stride);
            }
            NodeSpec::Gap { input } => {
                g.global_avg_pool(NodeId(*input));
            }
            NodeSpec::Flatten { input } => {
                g.flatten(NodeId(*input));
            }
            NodeSpec::Add { a, b } => {
                g.add(NodeId(*a), NodeId(*b));
            }
        }
    }
    for &o in &manifest.outputs {
        g.mark_output(NodeId(o));
    }
    Ok(g)
}

/// Replay the static-mode output-grid chain over the whole graph and
/// check each quantizable node's grid against the stored `static` spec
/// bit-for-bit. Returns one grid per node.
fn replay_static_grids(manifest: &Manifest, input_q: QOut) -> Result<Vec<QOut>, ArtifactError> {
    let qids = manifest.quantizable();
    let mut qslot = vec![None; manifest.nodes.len()];
    for (j, &idx) in qids.iter().enumerate() {
        qslot[idx] = Some(j);
    }
    let mut grids: Vec<QOut> = Vec::with_capacity(manifest.nodes.len());
    for (i, spec) in manifest.nodes.iter().enumerate() {
        let q = match spec {
            NodeSpec::Input => input_q,
            NodeSpec::Conv { .. } | NodeSpec::DwConv { .. } | NodeSpec::Linear { .. } => {
                let j = qslot[i].ok_or_else(|| bad_variant(format!("node {i}: no calib slot")))?;
                let (lo, hi) = manifest.calib[j]
                    .ranges
                    .first()
                    .copied()
                    .ok_or_else(|| bad_variant(format!("node {i}: empty range table")))?;
                let qp = QParams::from_range(lo, hi, 8);
                let q = QOut { scale: qp.scale, zero: qp.zero_point };
                let ss = &manifest.int8_layers[j].static_spec;
                if q.scale.to_bits() != ss.out_scale.to_bits() || q.zero != ss.out_zero {
                    return Err(bad_variant(format!(
                        "node {i}: stored static grid disagrees with frozen ranges"
                    )));
                }
                q
            }
            NodeSpec::Relu { input }
            | NodeSpec::Relu6 { input }
            | NodeSpec::MaxPool { input, .. }
            | NodeSpec::Gap { input }
            | NodeSpec::Flatten { input } => grids[*input],
            NodeSpec::Add { a, b } => add_grid(grids[*a], grids[*b]),
        };
        grids.push(q);
    }
    Ok(grids)
}

/// Decode + semantically verify the int8 pieces of every quantizable
/// node: the stored `bq{i}` / `rq{i}` / `rs{i}` sections must equal what
/// [`fold_bias`] / [`build_requant`] / FC row-summing recompute from the
/// decoded kernel, bias and grid chain — bit for bit.
fn decode_pieces(
    manifest: &Manifest,
    payload: &[u8],
    grids: &[QOut],
) -> Result<Vec<Pieces>, ArtifactError> {
    let qids = manifest.quantizable();
    let mut pieces = Vec::with_capacity(qids.len());
    for (j, &idx) in qids.iter().enumerate() {
        let spec = &manifest.int8_layers[j];
        let node = &manifest.nodes[idx];
        let wshape = node
            .wshape()
            .ok_or_else(|| bad_variant(format!("node {idx}: not quantizable")))?;
        let is_linear = matches!(node, NodeSpec::Linear { .. });
        let kernel = decode_i8(manifest.section_bytes(payload, &format!("k{idx}"))?);
        let kernel = Arc::new(Tensor::from_vec(Shape::new(wshape), kernel));
        let bias_f = decode_f32(manifest.section_bytes(payload, &format!("b{idx}"))?);
        let in_id = node
            .inputs()
            .first()
            .copied()
            .ok_or_else(|| bad_variant(format!("node {idx}: no input")))?;
        let in_q = grids[in_id];

        let bias_q = decode_i32(manifest.section_bytes(payload, &format!("bq{idx}"))?);
        let mut bq_check = Vec::new();
        fold_bias(&bias_f, in_q.scale, &spec.s_w, &mut bq_check);
        if bq_check != bias_q {
            return Err(bad_variant(format!("node {idx}: folded bias drift (bq section)")));
        }

        let requant = build_requant(in_q.scale, &spec.s_w, grids[idx]);
        let rq_stored = decode_i32(manifest.section_bytes(payload, &format!("rq{idx}"))?);
        let rq_check: Vec<i32> =
            requant.multipliers.iter().flat_map(|m| [m.multiplier, m.shift]).collect();
        if rq_check != rq_stored
            || requant.output_offset != spec.static_spec.offset
            || requant.act_min != spec.static_spec.act_min
            || requant.act_max != spec.static_spec.act_max
        {
            return Err(bad_variant(format!("node {idx}: requant drift (rq section)")));
        }

        let w_row_sums = if is_linear {
            let stored = decode_i32(manifest.section_bytes(payload, &format!("rs{idx}"))?);
            let check = crate::cmsis::fast::weight_row_sums(&kernel);
            if check != stored {
                return Err(bad_variant(format!("node {idx}: row-sum drift (rs section)")));
            }
            stored
        } else {
            Vec::new()
        };

        pieces.push(Pieces { kernel, bias_f, bias_q, w_row_sums, requant });
    }
    Ok(pieces)
}

/// Build one mode's lowered node program. All three modes share the same
/// `Arc`'d kernel tensors; only static mode carries the frozen grid,
/// folded bias and requant spec.
fn int8_nodes(
    manifest: &Manifest,
    pieces: &[Pieces],
    grids: &[QOut],
    mode: QuantMode,
) -> Result<Vec<Int8Node>, ArtifactError> {
    let is_static = mode == QuantMode::Static;
    let qids = manifest.quantizable();
    let mut qslot = vec![None; manifest.nodes.len()];
    for (j, &idx) in qids.iter().enumerate() {
        qslot[idx] = Some(j);
    }
    let mut nodes = Vec::with_capacity(manifest.nodes.len());
    for (i, spec) in manifest.nodes.iter().enumerate() {
        let op = match spec {
            NodeSpec::Input => Int8Op::Input,
            NodeSpec::Conv { .. } | NodeSpec::DwConv { .. } | NodeSpec::Linear { .. } => {
                let j = qslot[i].ok_or_else(|| bad_variant(format!("node {i}: no layer slot")))?;
                let p = &pieces[j];
                let ls = &manifest.int8_layers[j];
                let l = Int8Layer {
                    kernel: Arc::clone(&p.kernel),
                    s_w: ls.s_w.clone(),
                    bias_f: p.bias_f.clone(),
                    bias_q: if is_static { p.bias_q.clone() } else { Vec::new() },
                    w_row_sums: p.w_row_sums.clone(),
                    mu_w: ls.mu_w,
                    var_w: ls.var_w,
                    bias_mu: ls.bias_mu,
                    bias_var: ls.bias_var,
                    interval: ls.interval,
                    static_out: if is_static { Some(grids[i]) } else { None },
                    static_requant: if is_static { Some(p.requant.clone()) } else { None },
                };
                match spec {
                    NodeSpec::Conv { wshape, stride, pad, .. }
                    | NodeSpec::DwConv { wshape, stride, pad, .. } => {
                        let geom = ConvGeom::new(wshape[1], wshape[2], *stride, *pad);
                        if matches!(spec, NodeSpec::Conv { .. }) {
                            Int8Op::Conv { l, geom }
                        } else {
                            Int8Op::DwConv { l, geom }
                        }
                    }
                    _ => Int8Op::Linear { l },
                }
            }
            NodeSpec::Relu { .. } => Int8Op::Relu,
            NodeSpec::Relu6 { .. } => Int8Op::Relu6,
            NodeSpec::MaxPool { k, stride, .. } => Int8Op::MaxPool { k: *k, stride: *stride },
            NodeSpec::Gap { .. } => Int8Op::GlobalAvgPool,
            NodeSpec::Flatten { .. } => Int8Op::Flatten,
            NodeSpec::Add { .. } => Int8Op::Add,
        };
        nodes.push(Int8Node { op, inputs: spec.inputs().iter().map(|&x| NodeId(x)).collect() });
    }
    Ok(nodes)
}

/// A loaded artifact: the reconstructed model plus its full 13-cell
/// serving menu, every cell verified and bit-exact with the in-process
/// build the artifact was packed from.
pub struct ArtifactEngine {
    manifest: Manifest,
    model: Model,
    menu: Vec<(VariantKey, Arc<dyn Engine>)>,
    mapped: bool,
}

impl ArtifactEngine {
    /// Load + fully verify an artifact file, `mmap(2)`-backed where the
    /// platform allows (falling back to a plain read).
    pub fn load(path: &Path) -> Result<ArtifactEngine, ArtifactError> {
        Self::load_with_key(path, None)
    }

    /// [`ArtifactEngine::load`], additionally verifying the keyed-hash
    /// signature trailer when `key` is supplied: an unsigned file is
    /// [`ArtifactError::SignatureMissing`], a non-matching trailer
    /// [`ArtifactError::SignatureMismatch`]. Without a key, a trailer is
    /// stripped unverified.
    pub fn load_with_key(
        path: &Path,
        key: Option<&[u8]>,
    ) -> Result<ArtifactEngine, ArtifactError> {
        let backing = Backing::open(path)?;
        let mapped = backing.is_mapped();
        Self::build(backing.bytes(), mapped, key)
    }

    /// Load + fully verify an artifact from in-memory bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<ArtifactEngine, ArtifactError> {
        Self::build(bytes, false, None)
    }

    /// [`ArtifactEngine::from_bytes`] with signature verification (see
    /// [`ArtifactEngine::load_with_key`]).
    pub fn from_bytes_with_key(
        bytes: &[u8],
        key: Option<&[u8]>,
    ) -> Result<ArtifactEngine, ArtifactError> {
        Self::build(bytes, false, key)
    }

    fn build(
        bytes: &[u8],
        mapped: bool,
        key: Option<&[u8]>,
    ) -> Result<ArtifactEngine, ArtifactError> {
        // The signature trailer sits *outside* the pdq-artifact-v1
        // structure: strip (and with a key, verify) it before any header
        // interpretation, so `Manifest::validate`'s exact-payload-length
        // check keeps rejecting genuinely trailing garbage.
        let bytes = match key {
            Some(key) => verify_artifact(bytes, key)?,
            None => split_trailer(bytes).0,
        };
        let (manifest, payload) = split_artifact(bytes)?;
        manifest.validate(payload.len())?;
        manifest.verify_sections(payload)?;

        // v1 pins the input grid to the canonical [0, 1] int8 grid the
        // executors assume; a file declaring anything else is not ours.
        let canon = QParams::from_range(0.0, 1.0, 8);
        if manifest.input_scale.to_bits() != canon.scale.to_bits()
            || manifest.input_zero != canon.zero_point
        {
            return Err(bad_variant("input grid is not the canonical [0, 1] int8 grid"));
        }
        let input_q = QOut { scale: manifest.input_scale, zero: manifest.input_zero };

        let graph = Arc::new(rebuild_graph(&manifest, payload)?);
        let grids = replay_static_grids(&manifest, input_q)?;
        let pieces = decode_pieces(&manifest, payload, &grids)?;

        let key = |spec: VariantSpec| VariantKey { model: manifest.model.clone(), spec };
        let mut menu: Vec<(VariantKey, Arc<dyn Engine>)> = Vec::with_capacity(13);
        menu.push((key(VariantSpec::Fp32), Arc::new(FloatEngine::new(Arc::clone(&graph)))));

        // Fake-quant emulation cells: fresh executors with the frozen
        // calibration tables restored (bit-exact with `calibrate()` —
        // the restore path recomputes the same deterministic q-sets).
        for mode in MODES {
            let settings = QuantSettings {
                mode,
                granularity: Granularity::PerTensor,
                bits: 8,
                gamma: manifest.gamma,
                coverage: manifest.coverage,
            };
            let mut ex = QuantExecutor::new(Arc::clone(&graph), settings);
            for c in &manifest.calib {
                if !ex.restore_calibration(c.node, c.ranges.clone(), c.interval) {
                    return Err(bad_variant(format!(
                        "node {}: calibration restore refused",
                        c.node
                    )));
                }
            }
            if !ex.is_calibrated() {
                return Err(bad_variant("calibration table does not cover every layer"));
            }
            let spec = VariantSpec::FakeQuant { mode, gran: Granularity::PerTensor };
            menu.push((key(spec), Arc::new(QuantEngine::new(Arc::new(ex)))));
        }

        // True int8 cells: one base 8-bit program per mode (kernel
        // tensors shared by `Arc` across all three), rungs derived.
        for mode in MODES {
            let nodes = int8_nodes(&manifest, &pieces, &grids, mode)?;
            let base = Arc::new(Int8Executor::from_parts(
                &graph,
                nodes,
                mode,
                manifest.gamma,
                manifest.weight_gran,
                input_q,
            ));
            for bits in [8u32, 4, 2] {
                let ex = if bits == 8 {
                    Arc::clone(&base)
                } else {
                    Arc::new(base.rung(bits).map_err(bad_variant)?)
                };
                let spec =
                    VariantSpec::Int8 { mode, weight_gran: manifest.weight_gran, bits };
                menu.push((key(spec), Arc::new(Int8Engine::new(ex))));
            }
        }

        // The menu must line up with the manifest's declared wire list
        // (validate() already pinned that list to the canonical one).
        for ((k, _), want) in menu.iter().zip(&manifest.variants) {
            if &k.spec.wire() != want {
                return Err(bad_variant(format!(
                    "menu drift: built {:?}, declared {want:?}",
                    k.spec.wire()
                )));
            }
        }

        let model = Model {
            name: manifest.model.clone(),
            task: manifest.task,
            graph,
            num_outputs: manifest.outputs.len(),
            golden: None,
            hlo_path: None,
        };
        Ok(ArtifactEngine { manifest, model, menu, mapped })
    }

    /// The verified manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The reconstructed model (graph + identity; no golden fixture).
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The full serving menu, in canonical wire order.
    pub fn menu(&self) -> &[(VariantKey, Arc<dyn Engine>)] {
        &self.menu
    }

    /// Consume the loaded artifact, yielding the menu for registration.
    pub fn into_menu(self) -> Vec<(VariantKey, Arc<dyn Engine>)> {
        self.menu
    }

    /// Look up one engine by spec.
    pub fn engine(&self, spec: &VariantSpec) -> Option<Arc<dyn Engine>> {
        self.menu.iter().find(|(k, _)| &k.spec == spec).map(|(_, e)| Arc::clone(e))
    }

    /// Whether the file bytes came through `mmap(2)` (false: plain read
    /// or [`ArtifactEngine::from_bytes`]).
    pub fn was_mapped(&self) -> bool {
        self.mapped
    }
}

impl std::fmt::Debug for ArtifactEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactEngine")
            .field("model", &self.manifest.model)
            .field("epoch", &self.manifest.epoch)
            .field("menu", &self.menu.len())
            .field("mapped", &self.mapped)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::pack::{pack_model, PackOptions};
    use crate::coordinator::calibrate::demo_model;

    fn packed_demo() -> Vec<u8> {
        pack_model(&demo_model("demo"), PackOptions::default()).unwrap()
    }

    #[test]
    fn roundtrip_loads_full_menu() {
        let bytes = packed_demo();
        let eng = ArtifactEngine::from_bytes(&bytes).unwrap();
        assert_eq!(eng.menu().len(), 13);
        assert!(!eng.was_mapped());
        let wires: Vec<String> = eng.menu().iter().map(|(k, _)| k.spec.wire()).collect();
        assert_eq!(wires, eng.manifest().variants);
        assert_eq!(eng.model().name, "demo");
        // Every cell is buildable through the trait object.
        for (_, e) in eng.menu() {
            assert_eq!(e.input_shape(), eng.model().graph.input_shape());
        }
    }

    #[test]
    fn load_maps_on_unix() {
        let bytes = packed_demo();
        let path = std::env::temp_dir().join("pdq_artifact_load_roundtrip.pdqa");
        std::fs::write(&path, &bytes).unwrap();
        let eng = ArtifactEngine::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(eng.menu().len(), 13);
        assert_eq!(eng.was_mapped(), cfg!(unix));
    }

    #[test]
    fn bad_magic_and_truncation_are_typed() {
        let bytes = packed_demo();
        assert!(matches!(
            ArtifactEngine::from_bytes(&[]).unwrap_err(),
            ArtifactError::Truncated { .. }
        ));
        let mut evil = bytes.clone();
        evil[0] = b'X';
        assert!(matches!(
            ArtifactEngine::from_bytes(&evil).unwrap_err(),
            ArtifactError::BadMagic
        ));
        assert!(matches!(
            ArtifactEngine::from_bytes(&bytes[..bytes.len() - 1]).unwrap_err(),
            ArtifactError::Truncated { .. }
        ));
    }

    #[test]
    fn payload_bitflip_fails_section_crc() {
        let mut bytes = packed_demo();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        assert!(matches!(
            ArtifactEngine::from_bytes(&bytes).unwrap_err(),
            ArtifactError::ChecksumMismatch { .. }
        ));
    }

    #[test]
    fn manifest_bitflip_fails_manifest_crc() {
        let mut bytes = packed_demo();
        bytes[HEADER_LEN + 2] ^= 0x01;
        assert!(matches!(
            ArtifactEngine::from_bytes(&bytes).unwrap_err(),
            ArtifactError::ChecksumMismatch { section } if section == "manifest"
        ));
    }

    #[test]
    fn signed_artifact_loads_and_tamper_is_caught() {
        let mut signed = packed_demo();
        crate::artifact::sign_artifact(&mut signed, b"release-key");

        // Without a key the trailer is stripped and the menu loads.
        assert_eq!(ArtifactEngine::from_bytes(&signed).unwrap().menu().len(), 13);
        // With the right key it verifies then loads.
        let eng = ArtifactEngine::from_bytes_with_key(&signed, Some(b"release-key")).unwrap();
        assert_eq!(eng.menu().len(), 13);
        // Wrong key / unsigned-with-key are typed failures.
        assert!(matches!(
            ArtifactEngine::from_bytes_with_key(&signed, Some(b"wrong")).unwrap_err(),
            ArtifactError::SignatureMismatch
        ));
        assert!(matches!(
            ArtifactEngine::from_bytes_with_key(&packed_demo(), Some(b"release-key"))
                .unwrap_err(),
            ArtifactError::SignatureMissing
        ));
        // A body bitflip under an intact-looking trailer dies on the
        // signature, before any CRC layer runs.
        let mut evil = signed.clone();
        evil[HEADER_LEN + 1] ^= 0x04;
        assert!(matches!(
            ArtifactEngine::from_bytes_with_key(&evil, Some(b"release-key")).unwrap_err(),
            ArtifactError::SignatureMismatch
        ));
    }

    #[test]
    fn crc_consistent_tamper_dies_on_semantic_cross_check() {
        // Flip a folded-bias value AND fix up the section + manifest CRCs:
        // the checksum layers pass, the fold_bias recomputation must not.
        let bytes = packed_demo();
        let (mut manifest, payload) = split_artifact(&bytes).unwrap();
        let mut payload = payload.to_vec();
        let pos = manifest.sections.iter().position(|e| e.name.starts_with("bq")).unwrap();
        let (off, len) = (manifest.sections[pos].off, manifest.sections[pos].len);
        let mut vals = decode_i32(&payload[off..off + len]);
        vals[0] = vals[0].wrapping_add(1);
        for (i, v) in vals.iter().enumerate() {
            payload[off + i * 4..off + i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        manifest.sections[pos].crc = crc32(&payload[off..off + len]);
        let rebuilt = crate::artifact::pack::assemble(&manifest, &payload).unwrap();
        assert!(matches!(
            ArtifactEngine::from_bytes(&rebuilt).unwrap_err(),
            ArtifactError::BadVariant(why) if why.contains("bq")
        ));
    }
}
