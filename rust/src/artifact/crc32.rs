//! CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) — the checksum
//! used for the manifest and every payload section. Table is built at
//! compile time; no dependencies.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (IEEE, reflected, init/xorout `!0`). Matches zlib's
/// `crc32()`: `crc32(b"123456789") == 0xCBF4_3926`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::crc32;

    #[test]
    fn check_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_and_sensitivity() {
        assert_eq!(crc32(b""), 0);
        let a = crc32(b"pdq-artifact-v1");
        let b = crc32(b"pdq-artifact-v2");
        assert_ne!(a, b);
        // Single bit flip anywhere must change the sum.
        let base = crc32(&[0u8; 64]);
        for byte in 0..64 {
            let mut buf = [0u8; 64];
            buf[byte] = 1;
            assert_ne!(crc32(&buf), base, "bit flip at byte {byte} undetected");
        }
    }
}
