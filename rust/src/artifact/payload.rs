//! Payload assembly and decoding: [`ALIGN`]-aligned little-endian
//! sections with per-section CRC32, matching the canonical layout
//! declared by [`super::manifest::Manifest::expected_layout`].

use super::crc32::crc32;
use super::manifest::{SectionDtype, SectionEntry};
use super::ALIGN;

/// Appends sections to a growing payload buffer, recording the checksum
/// table as it goes. Offsets come out identical to the manifest's
/// canonical layout because both pad the same way in the same order.
pub(crate) struct PayloadWriter {
    buf: Vec<u8>,
    sections: Vec<SectionEntry>,
}

impl PayloadWriter {
    pub(crate) fn new() -> Self {
        Self { buf: Vec::new(), sections: Vec::new() }
    }

    fn begin(&mut self) -> usize {
        while self.buf.len() % ALIGN != 0 {
            self.buf.push(0);
        }
        self.buf.len()
    }

    fn commit(&mut self, name: &str, off: usize, dtype: SectionDtype) {
        let bytes = &self.buf[off..];
        self.sections.push(SectionEntry {
            name: name.to_string(),
            off,
            len: bytes.len(),
            crc: crc32(bytes),
            dtype,
        });
    }

    /// Append an `f32` section (exact little-endian bit patterns).
    pub(crate) fn f32s(&mut self, name: &str, data: &[f32]) {
        let off = self.begin();
        for v in data {
            self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        self.commit(name, off, SectionDtype::F32);
    }

    /// Append an `i32` section (little-endian).
    pub(crate) fn i32s(&mut self, name: &str, data: &[i32]) {
        let off = self.begin();
        for v in data {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        self.commit(name, off, SectionDtype::I32);
    }

    /// Append a raw int8 section.
    pub(crate) fn i8s(&mut self, name: &str, data: &[i8]) {
        let off = self.begin();
        self.buf.extend(data.iter().map(|&v| v as u8));
        self.commit(name, off, SectionDtype::I8);
    }

    /// Final payload bytes + checksum table, in write order.
    pub(crate) fn finish(self) -> (Vec<u8>, Vec<SectionEntry>) {
        (self.buf, self.sections)
    }
}

/// Decode an `f32` section (byte length is validated to be a multiple of
/// 4 by the canonical-layout check before this is called).
pub(crate) fn decode_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
        .collect()
}

/// Decode an `i32` section.
pub(crate) fn decode_i32(bytes: &[u8]) -> Vec<i32> {
    bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Decode an int8 section.
pub(crate) fn decode_i8(bytes: &[u8]) -> Vec<i8> {
    bytes.iter().map(|&b| b as i8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_are_aligned_and_roundtrip() {
        let mut w = PayloadWriter::new();
        w.f32s("w1", &[1.5, -2.25, f32::MIN_POSITIVE]);
        w.i8s("k1", &[-128, -1, 0, 1, 127]);
        w.i32s("bq1", &[i32::MIN, -7, i32::MAX]);
        let (buf, sections) = w.finish();
        assert_eq!(sections.len(), 3);
        for e in &sections {
            assert_eq!(e.off % ALIGN, 0, "section {} misaligned", e.name);
            assert_eq!(crc32(&buf[e.off..e.off + e.len]), e.crc);
        }
        assert_eq!(decode_f32(&buf[sections[0].off..sections[0].off + sections[0].len]), vec![
            1.5,
            -2.25,
            f32::MIN_POSITIVE
        ]);
        assert_eq!(
            decode_i8(&buf[sections[1].off..sections[1].off + sections[1].len]),
            vec![-128, -1, 0, 1, 127]
        );
        assert_eq!(
            decode_i32(&buf[sections[2].off..sections[2].off + sections[2].len]),
            vec![i32::MIN, -7, i32::MAX]
        );
        // Payload ends at the last section's end — no trailing pad.
        assert_eq!(buf.len(), sections[2].off + sections[2].len);
    }

    #[test]
    fn nan_bit_patterns_survive() {
        let weird = f32::from_bits(0x7FC0_1234); // a specific NaN payload
        let mut w = PayloadWriter::new();
        w.f32s("w1", &[weird]);
        let (buf, sections) = w.finish();
        let back = decode_f32(&buf[..sections[0].len]);
        assert_eq!(back[0].to_bits(), 0x7FC0_1234);
    }
}
