//! Inspection: verify an artifact and pretty-print what it carries.
//!
//! `pdq inspect` is the operational trust tool: it runs the exact same
//! verification layers as the loader (header, manifest CRC, structural
//! validation, per-section CRCs) *without* constructing engines, so a
//! corrupt or hostile file is reported with its typed error and a
//! nonzero exit before anything executable exists.

use std::path::Path;

use super::load::split_artifact;
use super::manifest::Manifest;
use super::mmapfile::Backing;
use super::sign::{split_trailer, verify_artifact};
use super::{ArtifactError, HEADER_LEN};
use crate::util::json::Json;

/// What the keyed-hash trailer told us about this artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SignatureStatus {
    /// No signature trailer on the file.
    Unsigned,
    /// A trailer is present but no verification key was supplied, so it
    /// was stripped, not checked.
    Present,
    /// A trailer is present and matched the supplied key.
    Verified,
}

impl SignatureStatus {
    /// Wire/report spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            SignatureStatus::Unsigned => "unsigned",
            SignatureStatus::Present => "signed (unverified: no key)",
            SignatureStatus::Verified => "signed (verified)",
        }
    }
}

/// Everything `pdq inspect` reports about a verified artifact.
#[derive(Clone, Debug)]
pub struct InspectReport {
    /// The parsed, validated manifest.
    pub manifest: Manifest,
    /// Total file length in bytes (including any signature trailer).
    pub file_len: usize,
    /// Manifest JSON length in bytes (from the header).
    pub manifest_len: usize,
    /// Payload length in bytes (after the alignment pad).
    pub payload_len: usize,
    /// Whether the file bytes came through `mmap(2)`.
    pub mapped: bool,
    /// Signature trailer status (keyed-hash, `PDQSIG1`).
    pub signature: SignatureStatus,
}

/// Verify artifact bytes end to end and build the report. Fails with the
/// loader's typed error on any corruption.
pub fn inspect_bytes(bytes: &[u8]) -> Result<InspectReport, ArtifactError> {
    inspect_bytes_with_key(bytes, None)
}

/// [`inspect_bytes`], additionally verifying the keyed-hash signature
/// trailer when `key` is supplied. With a key, an unsigned file is
/// [`ArtifactError::SignatureMissing`] and a non-matching trailer is
/// [`ArtifactError::SignatureMismatch`]; without one, a trailer is
/// stripped and reported as present-but-unverified.
pub fn inspect_bytes_with_key(
    bytes: &[u8],
    key: Option<&[u8]>,
) -> Result<InspectReport, ArtifactError> {
    let (body, signature) = match key {
        Some(key) => (verify_artifact(bytes, key)?, SignatureStatus::Verified),
        None => {
            let (body, tag) = split_trailer(bytes);
            let status = if tag.is_some() {
                SignatureStatus::Present
            } else {
                SignatureStatus::Unsigned
            };
            (body, status)
        }
    };
    let (manifest, payload) = split_artifact(body)?;
    manifest.validate(payload.len())?;
    manifest.verify_sections(payload)?;
    let manifest_len =
        u32::from_le_bytes([body[6], body[7], body[8], body[9]]) as usize;
    Ok(InspectReport {
        manifest,
        file_len: bytes.len(),
        manifest_len,
        payload_len: payload.len(),
        mapped: false,
        signature,
    })
}

/// [`inspect_bytes`] on a file, `mmap(2)`-backed where possible.
pub fn inspect_path(path: &Path) -> Result<InspectReport, ArtifactError> {
    inspect_path_with_key(path, None)
}

/// [`inspect_bytes_with_key`] on a file, `mmap(2)`-backed where possible.
pub fn inspect_path_with_key(
    path: &Path,
    key: Option<&[u8]>,
) -> Result<InspectReport, ArtifactError> {
    let backing = Backing::open(path)?;
    let mut report = inspect_bytes_with_key(backing.bytes(), key)?;
    report.mapped = backing.is_mapped();
    Ok(report)
}

impl InspectReport {
    /// Human-readable report (the default `pdq inspect` output).
    pub fn render_text(&self) -> String {
        let m = &self.manifest;
        let mut s = String::new();
        let params: usize = m
            .nodes
            .iter()
            .filter_map(|n| n.wshape())
            .map(|w| w.iter().product::<usize>() + w[0])
            .sum();
        s.push_str(&format!("pdq-artifact-v1  {:?}\n", m.model));
        s.push_str(&format!(
            "  epoch {}  task {}  created_unix {}\n",
            m.epoch,
            m.task.name(),
            m.created_unix
        ));
        s.push_str(&format!(
            "  file {} B = header {} + manifest {} + pad + payload {}  ({})\n",
            self.file_len,
            HEADER_LEN,
            self.manifest_len,
            self.payload_len,
            if self.mapped { "mmap" } else { "read" }
        ));
        s.push_str(&format!(
            "  graph: {} nodes ({} quantizable), {} params, input {:?}\n",
            m.nodes.len(),
            m.quantizable().len(),
            params,
            m.input_shape.dims()
        ));
        for (o, sh) in m.outputs.iter().zip(&m.output_shapes) {
            s.push_str(&format!("  output: node {o} {:?}\n", sh.dims()));
        }
        s.push_str(&format!(
            "  knobs: gamma {}  coverage {}  weight_gran {}  input grid s={} z={}\n",
            m.gamma,
            m.coverage,
            match m.weight_gran {
                crate::quant::Granularity::PerTensor => "per-tensor",
                crate::quant::Granularity::PerChannel => "per-channel",
            },
            m.input_scale,
            m.input_zero
        ));
        s.push_str(&format!(
            "  calibration: {} images ({})\n",
            m.calib_images, m.calib_source
        ));
        s.push_str(&format!("  signature: {}\n", self.signature.as_str()));
        s.push_str(&format!("  variants ({}):\n", m.variants.len()));
        for v in &m.variants {
            s.push_str(&format!("    {v}\n"));
        }
        s.push_str(&format!("  sections ({}), all CRCs verified:\n", m.sections.len()));
        for e in &m.sections {
            s.push_str(&format!(
                "    {:<8} off {:>8}  len {:>8}  {:<3}  crc 0x{:08x}\n",
                e.name,
                e.off,
                e.len,
                e.dtype.wire(),
                e.crc
            ));
        }
        s
    }

    /// Machine-readable report (`pdq inspect --json`).
    pub fn render_json(&self) -> String {
        let mut j = Json::obj();
        j.set("file_len", self.file_len)
            .set("manifest_len", self.manifest_len)
            .set("payload_len", self.payload_len)
            .set("mapped", self.mapped)
            .set("verified", true)
            .set("signature", self.signature.as_str())
            .set("manifest", self.manifest.to_json());
        j.to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::pack::{pack_model, PackOptions};
    use crate::coordinator::calibrate::demo_model;

    #[test]
    fn inspect_reports_verified_artifact() {
        let bytes = pack_model(&demo_model("demo"), PackOptions::default()).unwrap();
        let report = inspect_bytes(&bytes).unwrap();
        assert_eq!(report.file_len, bytes.len());
        let text = report.render_text();
        assert!(text.contains("pdq-artifact-v1"));
        assert!(text.contains("\"demo\""));
        assert!(text.contains("variants (13)"));
        let json = Json::parse(&report.render_json()).unwrap();
        assert_eq!(json.get("verified").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(
            json.get("manifest").and_then(|m| m.get("model")).and_then(|v| v.as_str()),
            Some("demo")
        );
    }

    #[test]
    fn inspect_rejects_corruption() {
        let mut bytes = pack_model(&demo_model("demo"), PackOptions::default()).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x10;
        assert!(matches!(
            inspect_bytes(&bytes).unwrap_err(),
            ArtifactError::ChecksumMismatch { .. }
        ));
    }

    #[test]
    fn inspect_reports_signature_status() {
        let bytes = pack_model(&demo_model("demo"), PackOptions::default()).unwrap();
        // Unsigned, no key: fine, reported as unsigned.
        let rep = inspect_bytes(&bytes).unwrap();
        assert_eq!(rep.signature, SignatureStatus::Unsigned);
        assert!(rep.render_text().contains("signature: unsigned"));

        // Signed, no key: verification is skipped but presence reported.
        let mut signed = bytes.clone();
        crate::artifact::sign_artifact(&mut signed, b"release-key");
        let rep = inspect_bytes(&signed).unwrap();
        assert_eq!(rep.signature, SignatureStatus::Present);
        assert_eq!(rep.file_len, signed.len());

        // Signed, right key: verified (and the report line says so).
        let rep = inspect_bytes_with_key(&signed, Some(b"release-key")).unwrap();
        assert_eq!(rep.signature, SignatureStatus::Verified);
        assert!(rep.render_text().contains("signed (verified)"));
        let json = Json::parse(&rep.render_json()).unwrap();
        assert_eq!(
            json.get("signature").and_then(|v| v.as_str()),
            Some("signed (verified)")
        );

        // Signed, wrong key / unsigned-with-key: typed failures.
        assert!(matches!(
            inspect_bytes_with_key(&signed, Some(b"other-key")).unwrap_err(),
            ArtifactError::SignatureMismatch
        ));
        assert!(matches!(
            inspect_bytes_with_key(&bytes, Some(b"release-key")).unwrap_err(),
            ArtifactError::SignatureMissing
        ));
    }
}
