//! SIGTERM/SIGINT → a process-global shutdown flag.
//!
//! `std` exposes no signal API and the crate policy is std-only, but std
//! already links libc on every unix target, so a one-line `extern "C"`
//! declaration of `signal(2)` is all the binding we need. The handler does
//! the only async-signal-safe thing possible — a relaxed atomic store —
//! and the front door's [`crate::net::frontdoor::FrontDoor::wait`] loop
//! polls the flag from normal thread context to run the graceful drain.

use std::sync::atomic::{AtomicBool, Ordering};

static TERM_REQUESTED: AtomicBool = AtomicBool::new(false);

/// Has SIGTERM/SIGINT been delivered since [`install_term_handler`]?
pub fn term_requested() -> bool {
    TERM_REQUESTED.load(Ordering::SeqCst)
}

/// Test hook / programmatic trigger: behave as if SIGTERM arrived.
pub fn request_term() {
    TERM_REQUESTED.store(true, Ordering::SeqCst);
}

/// Route SIGTERM and SIGINT to the flag. Idempotent; later installs for the
/// same signals just re-register the same handler.
#[cfg(unix)]
pub fn install_term_handler() {
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    extern "C" fn on_signal(_sig: i32) {
        TERM_REQUESTED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        // `sighandler_t signal(int signum, sighandler_t handler)` — both
        // handler types are plain pointers, passed as usize.
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
    }
}

/// Non-unix fallback: no signal wiring; programmatic shutdown
/// ([`request_term`] / `FrontDoor::shutdown`) still works.
#[cfg(not(unix))]
pub fn install_term_handler() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn programmatic_trigger_sets_flag() {
        // NOTE: the flag is process-global, so this test never *clears* it;
        // it only asserts the observable transition.
        install_term_handler();
        request_term();
        assert!(term_requested());
    }
}
