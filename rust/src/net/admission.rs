//! Per-variant admission control: a bounded in-flight gate.
//!
//! The coordinator's mpsc queues are unbounded, so under overload the server
//! would buffer arbitrarily many requests and every latency percentile would
//! grow without bound. `Admission` bounds the number of requests *admitted
//! but not yet answered* per variant; past the limit the caller sheds load
//! (the front door answers `429` with a `Retry-After` hint) instead of
//! queueing. A [`Permit`] is RAII: dropping it — after the response was
//! delivered, or on any early-exit path — frees the slot.
//!
//! The key map sits behind a `RwLock` so the model zoo can add and remove
//! variants at runtime (hot load/unload), but the lock is only ever write-
//! held for those rare membership changes: steady-state acquisition takes
//! a shared read lock just long enough to clone the slot's `Arc`, then
//! does a lock-free CAS on the atomic. A [`Permit`] holds its own `Arc`
//! to the counter, so permits issued before a key was removed still
//! release correctly afterwards — no leaked depth across an unload.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

/// Why admission was denied.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    UnknownKey,
    /// The variant is at its in-flight limit; `depth` is the limit that was
    /// hit (callers turn this into a retry hint).
    Full { depth: usize },
}

/// An admitted request's slot. Freed on drop.
pub struct Permit {
    slot: Arc<AtomicUsize>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.slot.fetch_sub(1, Ordering::AcqRel);
    }
}

/// The gate. `limit == 0` means unbounded (depth is still tracked, so
/// `/metrics` can report it). The limit itself is atomic so the SLO
/// autopilot can retune queue depth live without pausing admissions.
pub struct Admission<K: Ord> {
    limit: AtomicUsize,
    slots: RwLock<BTreeMap<K, Arc<AtomicUsize>>>,
}

impl<K: Ord + Clone> Admission<K> {
    pub fn new(limit: usize, keys: impl IntoIterator<Item = K>) -> Self {
        let slots =
            keys.into_iter().map(|k| (k, Arc::new(AtomicUsize::new(0)))).collect();
        Self { limit: AtomicUsize::new(limit), slots: RwLock::new(slots) }
    }

    pub fn limit(&self) -> usize {
        self.limit.load(Ordering::Acquire)
    }

    /// Retune the in-flight limit live (0 = unbounded). Already-admitted
    /// requests keep their permits; a shrink only gates *new* admissions,
    /// so depth drains down to the new limit rather than dropping work.
    pub fn set_limit(&self, limit: usize) {
        self.limit.store(limit, Ordering::Release);
    }

    /// Add a key (hot load). Idempotent: an existing counter is kept, so
    /// in-flight depth survives a racing re-add.
    pub fn insert(&self, key: K) {
        self.slots
            .write()
            .unwrap()
            .entry(key)
            .or_insert_with(|| Arc::new(AtomicUsize::new(0)));
    }

    /// Remove a key (hot unload). New acquisitions fail with
    /// [`AdmissionError::UnknownKey`]; already-issued permits keep their
    /// `Arc` to the counter and release normally.
    pub fn remove(&self, key: &K) -> bool {
        self.slots.write().unwrap().remove(key).is_some()
    }

    /// Try to admit one request for `key`.
    pub fn try_acquire(&self, key: &K) -> Result<Permit, AdmissionError> {
        let slot = {
            let slots = self.slots.read().unwrap();
            Arc::clone(slots.get(key).ok_or(AdmissionError::UnknownKey)?)
        };
        let limit = self.limit.load(Ordering::Acquire);
        if limit == 0 {
            slot.fetch_add(1, Ordering::AcqRel);
            return Ok(Permit { slot });
        }
        let mut cur = slot.load(Ordering::Acquire);
        loop {
            if cur >= limit {
                return Err(AdmissionError::Full { depth: limit });
            }
            match slot.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return Ok(Permit { slot }),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current in-flight depth for `key` (0 for unknown keys).
    pub fn depth(&self, key: &K) -> usize {
        self.slots
            .read()
            .unwrap()
            .get(key)
            .map(|s| s.load(Ordering::Acquire))
            .unwrap_or(0)
    }

    /// Snapshot of every (key, depth) pair — the `/metrics` gauge source.
    pub fn depths(&self) -> Vec<(K, usize)> {
        self.slots
            .read()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.load(Ordering::Acquire)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_acquire_release() {
        let a: Admission<String> = Admission::new(2, ["v".to_string()]);
        let p1 = a.try_acquire(&"v".to_string()).unwrap();
        let p2 = a.try_acquire(&"v".to_string()).unwrap();
        assert_eq!(a.depth(&"v".to_string()), 2);
        assert_eq!(
            a.try_acquire(&"v".to_string()).unwrap_err(),
            AdmissionError::Full { depth: 2 }
        );
        drop(p1);
        assert_eq!(a.depth(&"v".to_string()), 1);
        let p3 = a.try_acquire(&"v".to_string()).unwrap();
        drop(p2);
        drop(p3);
        assert_eq!(a.depth(&"v".to_string()), 0);
    }

    #[test]
    fn unknown_key_rejected() {
        let a: Admission<String> = Admission::new(1, ["v".to_string()]);
        assert_eq!(a.try_acquire(&"ghost".to_string()).unwrap_err(), AdmissionError::UnknownKey);
        assert_eq!(a.depth(&"ghost".to_string()), 0);
    }

    #[test]
    fn zero_limit_is_unbounded_but_counted() {
        let a: Admission<u32> = Admission::new(0, [7u32]);
        let permits: Vec<Permit> = (0..100).map(|_| a.try_acquire(&7).unwrap()).collect();
        assert_eq!(a.depth(&7), 100);
        drop(permits);
        assert_eq!(a.depth(&7), 0);
    }

    #[test]
    fn dynamic_keys_and_permits_survive_removal() {
        let a: Admission<String> = Admission::new(2, ["a".to_string()]);
        assert_eq!(a.try_acquire(&"b".to_string()).unwrap_err(), AdmissionError::UnknownKey);
        a.insert("b".to_string());
        let pb = a.try_acquire(&"b".to_string()).unwrap();
        assert_eq!(a.depth(&"b".to_string()), 1);
        // Unload while a request is in flight: the key disappears for new
        // admissions, but the outstanding permit still releases cleanly.
        assert!(a.remove(&"b".to_string()));
        assert!(!a.remove(&"b".to_string()));
        assert_eq!(a.try_acquire(&"b".to_string()).unwrap_err(), AdmissionError::UnknownKey);
        assert_eq!(a.depth(&"b".to_string()), 0, "removed key reads as empty");
        drop(pb); // must not panic or underflow
        // Re-add after removal starts from a fresh counter.
        a.insert("b".to_string());
        assert_eq!(a.depth(&"b".to_string()), 0);
        let _p1 = a.try_acquire(&"b".to_string()).unwrap();
        let _p2 = a.try_acquire(&"b".to_string()).unwrap();
        assert_eq!(
            a.try_acquire(&"b".to_string()).unwrap_err(),
            AdmissionError::Full { depth: 2 }
        );
    }

    #[test]
    fn live_limit_retune_gates_new_admissions_only() {
        let a: Admission<String> = Admission::new(4, ["v".to_string()]);
        let held: Vec<Permit> = (0..4).map(|_| a.try_acquire(&"v".to_string()).unwrap()).collect();
        // Shrink below the in-flight depth: nothing is dropped, but new
        // admissions see the new limit immediately.
        a.set_limit(2);
        assert_eq!(a.limit(), 2);
        assert_eq!(a.depth(&"v".to_string()), 4, "held permits survive a shrink");
        assert_eq!(
            a.try_acquire(&"v".to_string()).unwrap_err(),
            AdmissionError::Full { depth: 2 }
        );
        drop(held);
        // Depth drained below the new limit: admissions flow again.
        let _p = a.try_acquire(&"v".to_string()).unwrap();
        let _q = a.try_acquire(&"v".to_string()).unwrap();
        assert_eq!(
            a.try_acquire(&"v".to_string()).unwrap_err(),
            AdmissionError::Full { depth: 2 }
        );
        // Growing back (and to unbounded) also takes effect live.
        a.set_limit(0);
        assert!(a.try_acquire(&"v".to_string()).is_ok());
    }

    #[test]
    fn concurrent_acquire_never_exceeds_limit() {
        let a: Arc<Admission<u8>> = Arc::new(Admission::new(4, [0u8]));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..8 {
            let a = Arc::clone(&a);
            let peak = Arc::clone(&peak);
            joins.push(std::thread::spawn(move || {
                let mut admitted = 0usize;
                for _ in 0..1000 {
                    if let Ok(p) = a.try_acquire(&0) {
                        admitted += 1;
                        let d = a.depth(&0);
                        peak.fetch_max(d, Ordering::SeqCst);
                        assert!(d <= 4, "depth {d} exceeded limit");
                        drop(p);
                    }
                }
                admitted
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert!(total > 0, "at least some acquisitions must succeed");
        assert_eq!(a.depth(&0), 0);
        assert!(peak.load(Ordering::SeqCst) <= 4);
    }
}
