//! The network serving front door — Layer 3's ingress.
//!
//! Everything here is std-only (the crate's no-new-deps policy): a
//! hand-rolled HTTP/1.1 framing layer over `TcpListener`, a fixed
//! connection pool, per-variant admission control, and a load-generation
//! harness, composing with the in-process [`crate::coordinator`] stack:
//!
//! ```text
//!  sockets ──▶ FrontDoor (accept + conn pool)
//!                 │  POST /v1/infer (wire.rs binary tensor protocol)
//!                 ▼
//!          Server::try_submit ──▶ Admission (bounded in-flight, 429 shed)
//!                 │ admitted
//!                 ▼
//!          Router ─▶ Batcher ─▶ Workers        GET /metrics | /healthz
//! ```
//!
//! - [`http`] — incremental request parser + response writer (keep-alive,
//!   read-timeout resumption, chunked transfer-encoding decode with hard
//!   limits; other transfer codings answer 501).
//! - [`threadpool`] — fixed pool with drain-on-join semantics.
//! - [`admission`] — the bounded in-flight gate and its RAII [`admission::Permit`].
//! - [`wire`] — the `/v1/infer` binary tensor protocol + blocking client
//!   with deadline-budgeted, jittered retries.
//! - [`frontdoor`] — listener, routing, graceful drain (SIGTERM-aware),
//!   slowloris deadlines and a max-connection cap.
//! - [`signal`] — SIGTERM/SIGINT → shutdown flag, via libc `signal(2)`.
//! - [`loadgen`] — open/closed-loop traffic generator → `BENCH_serving.json`.
//! - [`chaos`] — deterministic fault-injecting stream/listener (short
//!   reads, `WouldBlock` ticks, latency, mid-stream disconnects) for
//!   robustness tests; never corrupts bytes.

pub mod admission;
pub mod chaos;
pub mod frontdoor;
pub mod http;
pub mod loadgen;
pub mod signal;
pub mod threadpool;
pub mod wire;

pub use frontdoor::{FrontDoor, FrontDoorConfig};
pub use wire::Client;
