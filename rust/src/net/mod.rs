//! The network serving front door — Layer 3's ingress.
//!
//! Everything here is std-only (the crate's no-new-deps policy): a
//! hand-rolled HTTP/1.1 framing layer over `TcpListener`, a fixed
//! connection pool, per-variant admission control, and a load-generation
//! harness, composing with the in-process [`crate::coordinator`] stack:
//!
//! ```text
//!  sockets ──▶ FrontDoor (accept + conn pool)
//!                 │  POST /v1/infer (wire.rs binary tensor protocol)
//!                 ▼
//!          Server::try_submit ──▶ Admission (bounded in-flight, 429 shed)
//!                 │ admitted
//!                 ▼
//!          Router ─▶ Batcher ─▶ Workers        GET /metrics | /healthz
//! ```
//!
//! - [`http`] — incremental request parser + response writer (keep-alive,
//!   read-timeout resumption; chunked encoding deliberately out of scope).
//! - [`threadpool`] — fixed pool with drain-on-join semantics.
//! - [`admission`] — the bounded in-flight gate and its RAII [`admission::Permit`].
//! - [`wire`] — the `/v1/infer` binary tensor protocol + blocking client.
//! - [`frontdoor`] — listener, routing, graceful drain (SIGTERM-aware).
//! - [`signal`] — SIGTERM/SIGINT → shutdown flag, via libc `signal(2)`.
//! - [`loadgen`] — open/closed-loop traffic generator → `BENCH_serving.json`.

pub mod admission;
pub mod frontdoor;
pub mod http;
pub mod loadgen;
pub mod signal;
pub mod threadpool;
pub mod wire;

pub use frontdoor::{FrontDoor, FrontDoorConfig};
pub use wire::Client;
