//! Deterministic fault injection for the serving stack.
//!
//! Two layers, both seeded and fully reproducible:
//!
//! - [`ChaosStream`] wraps any `Read + Write` and injects *transport*
//!   faults: short reads/writes capped at a random chunk size, spurious
//!   `WouldBlock` ticks on the read side (what a socket read timeout looks
//!   like), optional latency, and a forced mid-stream disconnect after a
//!   byte budget. Used in-process around [`super::http::RequestReader`]
//!   in tests.
//! - [`ChaosListener`] is a std-only TCP proxy: it accepts connections and
//!   pumps bytes to a target address through the same fault model, with a
//!   per-connection seed derived from the base seed and the connection
//!   index. The CI chaos smoke puts it in front of `pdq serve`.
//!
//! The invariant both layers guarantee: **bytes are never corrupted,
//! reordered or duplicated** — faults are timing- and connection-level
//! only. Whatever traffic survives must therefore parse cleanly, which is
//! exactly what the chaos tests assert (zero malformed-input rejections on
//! the server, zero protocol errors in the load generator).

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::prng::Pcg32;

/// Fault-injection knobs. All randomness is drawn from a [`Pcg32`] seeded
/// with `seed`, so a failing configuration replays exactly.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    pub seed: u64,
    /// Cap on bytes moved per read/write; each op moves a uniform
    /// 1..=`max_chunk` bytes. 1 is the pathological byte-at-a-time peer.
    pub max_chunk: usize,
    /// Inject a read-side `WouldBlock` roughly once per this many ops
    /// (0 = never). Write-side blocking is not injected: blocking-socket
    /// writers treat `WouldBlock` as fatal, and real kernels don't surface
    /// it on blocking writes either.
    pub would_block_every: u32,
    /// Sleep `latency` on roughly 1-in-`latency_every` ops (0 = never).
    pub latency: Duration,
    pub latency_every: u32,
    /// Kill the stream after this many forwarded bytes: reads return EOF,
    /// writes return `BrokenPipe` (None = never). For [`ChaosListener`]
    /// this is chosen per connection via `disconnect_every`.
    pub disconnect_after: Option<u64>,
    /// Proxy only: roughly 1-in-N accepted connections get a random
    /// mid-stream disconnect budget (0 = no forced disconnects).
    pub disconnect_every: u32,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            seed: 0xC4A0_5EED,
            max_chunk: 7,
            would_block_every: 5,
            latency: Duration::ZERO,
            latency_every: 0,
            disconnect_after: None,
            disconnect_every: 0,
        }
    }
}

/// A `Read + Write` wrapper applying the [`ChaosConfig`] fault model.
pub struct ChaosStream<S> {
    inner: S,
    cfg: ChaosConfig,
    rng: Pcg32,
    /// Bytes moved in either direction (drives `disconnect_after`).
    moved: u64,
}

impl<S> ChaosStream<S> {
    pub fn new(inner: S, cfg: ChaosConfig) -> Self {
        let rng = Pcg32::new(cfg.seed);
        Self { inner, cfg, rng, moved: 0 }
    }

    pub fn into_inner(self) -> S {
        self.inner
    }

    fn disconnected(&self) -> bool {
        matches!(self.cfg.disconnect_after, Some(limit) if self.moved >= limit)
    }

    fn maybe_sleep(&mut self) {
        if self.cfg.latency_every > 0 && self.rng.below(self.cfg.latency_every) == 0 {
            std::thread::sleep(self.cfg.latency);
        }
    }

    fn chunk_cap(&mut self, want: usize) -> usize {
        let cap = 1 + self.rng.below(self.cfg.max_chunk.max(1) as u32) as usize;
        cap.min(want).max(1)
    }
}

impl<S: Read> Read for ChaosStream<S> {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        self.maybe_sleep();
        if self.disconnected() {
            return Ok(0); // peer-went-away EOF
        }
        if self.cfg.would_block_every > 0 && self.rng.below(self.cfg.would_block_every) == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WouldBlock,
                "injected read timeout",
            ));
        }
        let cap = self.chunk_cap(out.len());
        let n = self.inner.read(&mut out[..cap])?;
        self.moved += n as u64;
        Ok(n)
    }
}

impl<S: Write> Write for ChaosStream<S> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        self.maybe_sleep();
        if self.disconnected() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "injected disconnect",
            ));
        }
        let cap = self.chunk_cap(buf.len());
        let n = self.inner.write(&buf[..cap])?;
        self.moved += n as u64;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// A fault-injecting TCP front: listens, dials the target per accepted
/// connection, and pumps bytes both ways through the [`ChaosConfig`]
/// model. Each connection gets its own derived seed, so a run is
/// reproducible end to end from the base seed.
pub struct ChaosListener {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    accept_handle: Option<JoinHandle<()>>,
}

impl ChaosListener {
    /// Bind `listen_addr` (e.g. `127.0.0.1:0`) and start proxying to
    /// `target` (a `host:port`).
    pub fn start(listen_addr: &str, target: &str, cfg: ChaosConfig) -> std::io::Result<Self> {
        let listener = TcpListener::bind(listen_addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let target = target.to_string();
        let accept_handle = {
            let shutdown = Arc::clone(&shutdown);
            let accepted = Arc::clone(&accepted);
            std::thread::Builder::new()
                .name("chaos-accept".into())
                .spawn(move || accept_loop(listener, &target, cfg, &shutdown, &accepted))
                .expect("spawn chaos accept thread")
        };
        Ok(Self { local_addr, shutdown, accepted, accept_handle: Some(accept_handle) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.local_addr)
    }

    /// Connections accepted so far.
    pub fn connections(&self) -> u64 {
        self.accepted.load(Ordering::SeqCst)
    }

    /// Stop accepting, sever all pumps, and join every proxy thread.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosListener {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    target: &str,
    cfg: ChaosConfig,
    shutdown: &Arc<AtomicBool>,
    accepted: &AtomicU64,
) {
    let mut pumps: Vec<JoinHandle<()>> = Vec::new();
    let mut seeder = Pcg32::new(cfg.seed);
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((client, _)) => {
                let conn_id = accepted.fetch_add(1, Ordering::SeqCst);
                let server = match TcpStream::connect(target) {
                    Ok(s) => s,
                    Err(_) => continue, // target gone; drop the client
                };
                // Per-connection fault plan, all derived from the base
                // seed + connection index so runs replay exactly.
                let mut conn_cfg = cfg;
                conn_cfg.seed =
                    cfg.seed ^ (conn_id.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                conn_cfg.disconnect_after =
                    if cfg.disconnect_every > 0 && seeder.below(cfg.disconnect_every) == 0 {
                        Some(64 + seeder.below(8192) as u64)
                    } else {
                        None
                    };
                // Two pumps per connection; each side gets a distinct rng
                // stream (xor of direction tag) but shares the fault plan.
                let mut up_cfg = conn_cfg;
                up_cfg.seed ^= 0x5E1F_0000_0000_0001;
                let mut down_cfg = conn_cfg;
                down_cfg.seed ^= 0x5E1F_0000_0000_0002;
                // The response direction carries ~the same payload volume;
                // give it double the budget so a killed connection usually
                // dies mid-request OR mid-response, not always at the same
                // phase.
                down_cfg.disconnect_after = conn_cfg.disconnect_after.map(|b| b * 2);
                let (c2, s2) = match (client.try_clone(), server.try_clone()) {
                    (Ok(c), Ok(s)) => (c, s),
                    _ => continue,
                };
                pumps.push(spawn_pump("chaos-up", client, server, up_cfg, shutdown));
                pumps.push(spawn_pump("chaos-down", s2, c2, down_cfg, shutdown));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    // Severing both socket halves unblocks the pumps' reads; then join.
    for h in pumps {
        let _ = h.join();
    }
}

/// One direction of a proxied connection: read from `from` through the
/// fault model, write everything read to `to`. Exits on EOF, transport
/// error, the injected disconnect budget, or proxy shutdown (polled on
/// every read tick, so shutdown never hangs on an idle keep-alive peer).
fn spawn_pump(
    name: &str,
    from: TcpStream,
    to: TcpStream,
    cfg: ChaosConfig,
    shutdown: &Arc<AtomicBool>,
) -> JoinHandle<()> {
    let _ = from.set_read_timeout(Some(Duration::from_millis(50)));
    let shutdown = Arc::clone(shutdown);
    std::thread::Builder::new()
        .name(name.into())
        .spawn(move || {
            let mut chaos = ChaosStream::new(from, cfg);
            let mut to = to;
            let mut buf = [0u8; 4096];
            loop {
                if shutdown.load(Ordering::SeqCst) {
                    let _ = chaos.into_inner().shutdown(Shutdown::Both);
                    let _ = to.shutdown(Shutdown::Both);
                    return;
                }
                match chaos.read(&mut buf) {
                    Ok(0) => {
                        if chaos.disconnected() {
                            // Forced kill: sever both directions hard.
                            let _ = chaos.into_inner().shutdown(Shutdown::Both);
                            let _ = to.shutdown(Shutdown::Both);
                        } else {
                            // Clean EOF: half-close so the reverse pump
                            // can still deliver an in-flight response.
                            let _ = to.shutdown(Shutdown::Write);
                        }
                        return;
                    }
                    Ok(n) => {
                        if to.write_all(&buf[..n]).is_err() {
                            return;
                        }
                    }
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) => {}
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => {
                        let _ = to.shutdown(Shutdown::Both);
                        return;
                    }
                }
            }
        })
        .expect("spawn chaos pump thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::http::{ReadOutcome, RequestReader, DEFAULT_MAX_BODY_BYTES};
    use std::io::Cursor;

    fn read_all_chaos(data: &[u8], cfg: ChaosConfig) -> Vec<u8> {
        let mut s = ChaosStream::new(Cursor::new(data.to_vec()), cfg);
        let mut out = Vec::new();
        let mut buf = [0u8; 64];
        loop {
            match s.read(&mut buf) {
                Ok(0) => return out,
                Ok(n) => out.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
    }

    #[test]
    fn bytes_survive_chaos_unmodified() {
        let data: Vec<u8> = (0..4096u32).map(|i| (i * 31 % 251) as u8).collect();
        let cfg = ChaosConfig { seed: 7, max_chunk: 3, would_block_every: 3, ..Default::default() };
        assert_eq!(read_all_chaos(&data, cfg), data, "chaos must never corrupt bytes");
    }

    #[test]
    fn same_seed_same_schedule() {
        let cfg = ChaosConfig { seed: 42, ..Default::default() };
        let mut a = ChaosStream::new(Cursor::new(vec![0u8; 256]), cfg);
        let mut b = ChaosStream::new(Cursor::new(vec![0u8; 256]), cfg);
        let mut buf = [0u8; 64];
        for _ in 0..64 {
            let ra = a.read(&mut buf).map_err(|e| e.kind());
            let rb = b.read(&mut buf).map_err(|e| e.kind());
            assert_eq!(ra.is_err(), rb.is_err());
            if let (Ok(na), Ok(nb)) = (ra, rb) {
                assert_eq!(na, nb, "chunk schedule must be deterministic");
            }
        }
    }

    #[test]
    fn request_parses_identically_under_chaos() {
        let raw =
            b"POST /v1/infer HTTP/1.1\r\nContent-Length: 11\r\n\r\nhello world".to_vec();
        let plain = {
            let mut r = RequestReader::new(Cursor::new(raw.clone()), DEFAULT_MAX_BODY_BYTES);
            let ReadOutcome::Request(req) = r.read_request().unwrap() else { panic!() };
            req
        };
        for seed in 0..32u64 {
            let cfg = ChaosConfig {
                seed,
                max_chunk: 2,
                would_block_every: 2,
                ..Default::default()
            };
            let chaos = ChaosStream::new(Cursor::new(raw.clone()), cfg);
            let mut r = RequestReader::new(chaos, DEFAULT_MAX_BODY_BYTES);
            let req = loop {
                match r.read_request().unwrap() {
                    ReadOutcome::Request(req) => break req,
                    ReadOutcome::Timeout { .. } => {}
                    ReadOutcome::Eof => panic!("premature EOF (seed {seed})"),
                }
            };
            assert_eq!(req.method, plain.method, "seed {seed}");
            assert_eq!(req.body, plain.body, "seed {seed}");
        }
    }

    #[test]
    fn disconnect_budget_cuts_the_stream() {
        let cfg = ChaosConfig {
            seed: 3,
            max_chunk: 8,
            would_block_every: 0,
            disconnect_after: Some(10),
            ..Default::default()
        };
        let out = read_all_chaos(&[1u8; 1000], cfg);
        assert!(out.len() >= 10 && out.len() < 20, "got {} bytes", out.len());
        // Writes after the budget fail loudly rather than silently vanish.
        let mut s = ChaosStream::new(Cursor::new(Vec::new()), cfg);
        s.moved = 10;
        assert_eq!(
            s.write(b"x").unwrap_err().kind(),
            std::io::ErrorKind::BrokenPipe
        );
    }
}
