//! Socket-level load generation against the front door.
//!
//! Two disciplines:
//! - **Closed loop** — `concurrency` workers, each issuing the next request
//!   the moment the previous response arrives. Measures capacity: the
//!   achieved throughput IS the service rate at that concurrency.
//! - **Open loop** — requests fire on a fixed global schedule (`rps`),
//!   partitioned round-robin across the workers, *regardless* of whether
//!   earlier responses came back. Latency is measured from the request's
//!   **scheduled** start, so queueing delay caused by a slow server counts
//!   against it (the standard coordinated-omission correction; a worker
//!   that falls behind its slice sends late and the lateness is in the
//!   number). Measures behavior at a chosen offered load — this is where
//!   429 shedding and tail latency under overload become visible.
//!
//! Targets are discovered from `GET /v1/variants`, inputs are seeded
//! uniform noise per variant, and the report lands in `BENCH_serving.json`
//! (schema `pdq-serving-v2`; every `v1` field is kept). `v2` adds the
//! flight-recorder tie-in: per-variant counts of trace-carrying responses
//! plus a small sample of server trace IDs (resolvable against
//! `GET /v1/traces?id=` while the server is still up), and a snapshot of
//! the server's per-stage latency attribution from `GET /metrics`.
//!
//! **Overload sweep** ([`run_sweep`], `--sweep`): steps the offered
//! open-loop RPS from 1× to 10× of a measured (or given) baseline and
//! records, per step, the shed rate, latency tail, and the served-bits
//! histogram decoded from the response preambles — the degradation curve
//! of a precision-brownout server. A preliminary unloaded pass measures
//! each quantized variant's top-1 agreement against its model's fp32
//! variant over the wire. The report lands in `BENCH_degrade.json`
//! (schema `pdq-degrade-v1`).
//!
//! **Mid-run distribution shift** ([`ShiftSpec`], `--shift
//! corruption:severity@t`): from `t` seconds into the run every worker
//! switches to a corrupted copy of its input (built once, seeded — see
//! [`crate::data::corrupt`]). This is the closed-loop driver for the
//! online-adaptation demo: clean warm-up traffic, then a §5.2 corruption
//! shift the server's drift monitor should catch and recalibrate away.

use std::time::{Duration, Instant};

use crate::data::corrupt::{corrupt, Corruption};
use crate::engine::VariantKey;
use crate::net::wire::{Client, InferOutcome};
use crate::obs::TraceId;
use crate::tensor::{Shape, Tensor};
use crate::util::json::Json;
use crate::util::{stats, Pcg32};

/// A mid-run input-distribution shift: apply `corruption` at `severity`
/// to every request sent `at` or later after run start.
#[derive(Clone, Copy, Debug)]
pub struct ShiftSpec {
    /// Which §5.2 corruption to inject.
    pub corruption: Corruption,
    /// Severity 1–5.
    pub severity: u32,
    /// When the shift begins, relative to run start.
    pub at: Duration,
}

impl ShiftSpec {
    /// Parse the CLI grammar `corruption:severity@seconds`
    /// (e.g. `contrast:5@2`, `white_noise:3@1.5`).
    pub fn parse(s: &str) -> Result<ShiftSpec, String> {
        let (lhs, t) = s
            .split_once('@')
            .ok_or_else(|| format!("shift {s:?}: want corruption:severity@seconds"))?;
        let (name, sev) = lhs
            .split_once(':')
            .ok_or_else(|| format!("shift {s:?}: want corruption:severity@seconds"))?;
        let corruption = Corruption::from_name(name)?;
        let severity: u32 =
            sev.parse().map_err(|_| format!("shift severity {sev:?} is not an integer"))?;
        if !(1..=5).contains(&severity) {
            return Err(format!("shift severity must be 1..=5, got {severity}"));
        }
        let secs: f64 = t.parse().map_err(|_| format!("shift time {t:?} is not a number"))?;
        if !secs.is_finite() || secs < 0.0 {
            return Err(format!("shift time must be a finite number >= 0, got {t:?}"));
        }
        Ok(ShiftSpec { corruption, severity, at: Duration::from_secs_f64(secs) })
    }

    /// The CLI form back (`contrast:5@2`).
    pub fn display(&self) -> String {
        format!("{}:{}@{}", self.corruption.name(), self.severity, self.at.as_secs_f64())
    }
}

/// Traffic discipline.
#[derive(Clone, Copy, Debug)]
pub enum LoadMode {
    Open { rps: f64 },
    Closed,
}

/// Load-run configuration.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// `host:port` of a running front door.
    pub target: String,
    pub mode: LoadMode,
    /// Worker threads (each with its own keep-alive connection).
    pub concurrency: usize,
    pub duration: Duration,
    /// Variant wire names to drive; empty = every advertised variant.
    pub variants: Vec<String>,
    /// Model names to drive (every advertised variant of each, so traffic
    /// round-robins across the zoo). Unions with `variants`; both empty =
    /// everything.
    pub models: Vec<String>,
    pub seed: u64,
    /// Closed loop only: cap on honoring the server's 429 retry hint
    /// (zero = hammer without backing off).
    pub backoff_cap: Duration,
    /// Optional mid-run input-distribution shift.
    pub shift: Option<ShiftSpec>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            target: "127.0.0.1:8429".into(),
            mode: LoadMode::Closed,
            concurrency: 4,
            duration: Duration::from_secs(5),
            variants: Vec::new(),
            models: Vec::new(),
            seed: 0x10AD,
            backoff_cap: Duration::from_millis(50),
            shift: None,
        }
    }
}

/// One variant's aggregated numbers ("all" for the totals row).
#[derive(Clone, Debug)]
pub struct VariantReport {
    pub wire: String,
    pub sent: u64,
    pub ok: u64,
    /// 429 sheds.
    pub rejected: u64,
    /// Other non-200 HTTP responses.
    pub failed: u64,
    /// No HTTP response at all (transport errors) — the CI smoke asserts
    /// this stays zero.
    pub dropped: u64,
    pub mean_us: f32,
    pub p50_us: f32,
    pub p95_us: f32,
    pub p99_us: f32,
    /// OK responses by served precision (the `"bits"` response preamble
    /// field); key 0 collects responses from servers that predate it.
    pub served_bits: std::collections::BTreeMap<u32, u64>,
    /// OK responses whose preamble carried a server-echoed trace ID
    /// (zero unless the server ran with `--trace`).
    pub traced: u64,
    /// Sample of those trace IDs (first [`TRACE_ID_SAMPLE`] seen) — enough
    /// to pull full span breakdowns from `GET /v1/traces?id=` afterwards.
    pub trace_ids: Vec<String>,
}

/// Per-variant cap on sampled trace IDs in the report.
pub const TRACE_ID_SAMPLE: usize = 8;

impl VariantReport {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("variant", self.wire.as_str())
            .set("sent", self.sent)
            .set("ok", self.ok)
            .set("rejected", self.rejected)
            .set("failed", self.failed)
            .set("dropped", self.dropped)
            .set("reject_rate", if self.sent > 0 { self.rejected as f64 / self.sent as f64 } else { 0.0 })
            .set("mean_us", self.mean_us)
            .set("p50_us", self.p50_us)
            .set("p95_us", self.p95_us)
            .set("p99_us", self.p99_us);
        let mut bits = Json::obj();
        for (b, n) in &self.served_bits {
            bits.set(&b.to_string(), *n);
        }
        o.set("served_bits", bits)
            .set("traced", self.traced)
            .set(
                "trace_ids",
                Json::Arr(self.trace_ids.iter().map(|t| Json::Str(t.clone())).collect()),
            );
        o
    }
}

/// The full run report.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub mode: String,
    pub offered_rps: Option<f64>,
    pub concurrency: usize,
    pub duration_s: f64,
    pub achieved_rps: f64,
    /// The injected mid-run shift, in CLI form (`contrast:5@2`), if any.
    pub shift: Option<String>,
    pub total: VariantReport,
    pub per_variant: Vec<VariantReport>,
    /// Snapshot of the server's per-stage latency attribution (the
    /// `"stages"` object of `GET /metrics`), taken right after the run.
    /// `None` when the fetch failed or the server predates stage metrics.
    pub stages: Option<Json>,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        let mut cfg = Json::obj();
        cfg.set("mode", self.mode.as_str())
            .set("concurrency", self.concurrency)
            .set("duration_s", self.duration_s);
        if let Some(rps) = self.offered_rps {
            cfg.set("offered_rps", rps);
        }
        if let Some(shift) = &self.shift {
            cfg.set("shift", shift.as_str());
        }
        let mut o = Json::obj();
        o.set("schema", "pdq-serving-v2")
            .set("config", cfg)
            .set("achieved_rps", self.achieved_rps)
            .set("aggregate", self.total.to_json())
            .set(
                "per_variant",
                Json::Arr(self.per_variant.iter().map(|v| v.to_json()).collect()),
            );
        if let Some(stages) = &self.stages {
            o.set("stages", stages.clone());
        }
        o
    }

    /// Write the JSON report (`BENCH_serving.json`).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }
}

struct TargetVariant {
    key: VariantKey,
    wire: String,
    image: Tensor<f32>,
    /// Corrupted copy of `image`, sent once the shift is active.
    shifted: Option<Tensor<f32>>,
}

/// `GET /v1/variants` → the drive list, with one seeded-noise input tensor
/// per variant.
fn discover(cfg: &LoadgenConfig) -> Result<Vec<TargetVariant>, String> {
    let mut client = Client::new(&cfg.target);
    let parts = client.get("/v1/variants")?;
    if parts.status != 200 {
        return Err(format!("GET /v1/variants: http {}", parts.status));
    }
    let j = Json::parse(std::str::from_utf8(&parts.body).map_err(|e| e.to_string())?)?;
    let mut out = Vec::new();
    for (idx, v) in j
        .get("variants")
        .and_then(|v| v.as_arr())
        .ok_or("catalog missing \"variants\"")?
        .iter()
        .enumerate()
    {
        let wire = v.get("variant").and_then(|s| s.as_str()).ok_or("entry missing name")?;
        let model = wire.split('|').next().unwrap_or("");
        let unfiltered = cfg.variants.is_empty() && cfg.models.is_empty();
        if !unfiltered
            && !cfg.variants.iter().any(|w| w == wire)
            && !cfg.models.iter().any(|m| m == model)
        {
            continue;
        }
        let dims: Vec<usize> = v
            .get("input_shape")
            .and_then(|s| s.as_arr())
            .ok_or("entry missing input_shape")?
            .iter()
            .map(|d| {
                d.as_usize().ok_or_else(|| format!("non-integer dim in input_shape of {wire}"))
            })
            .collect::<Result<_, _>>()?;
        let shape = Shape::new(&dims);
        let mut rng = Pcg32::new(cfg.seed ^ (idx as u64).wrapping_mul(0x9E37_79B9));
        let data: Vec<f32> = (0..shape.numel()).map(|_| rng.uniform()).collect();
        let image = Tensor::from_vec(shape, data);
        // The shifted copy is corrupted once, deterministically, so every
        // post-shift request is identical (the drift is in the switch).
        let shifted = cfg.shift.map(|s| {
            let mut crng = Pcg32::new(cfg.seed ^ 0x5417_F7ED ^ idx as u64);
            corrupt(&image, s.corruption, s.severity, &mut crng)
        });
        out.push(TargetVariant {
            key: VariantKey::parse_wire(wire)?,
            wire: wire.to_string(),
            image,
            shifted,
        });
    }
    if out.is_empty() {
        return Err(if cfg.variants.is_empty() && cfg.models.is_empty() {
            "server advertises no variants".into()
        } else {
            format!(
                "none of variants={:?} models={:?} advertised by the server",
                cfg.variants, cfg.models
            )
        });
    }
    // Keep requested order deterministic for the round-robin mix.
    Ok(out)
}

#[derive(Clone, Copy)]
enum Outcome {
    Ok,
    Rejected,
    Failed,
    Dropped,
}

struct Rec {
    variant: usize,
    outcome: Outcome,
    us: f32,
    /// Served precision of an OK response (0 otherwise / legacy server).
    bits: u32,
    /// Server-echoed trace ID of an OK response, when tracing was armed.
    trace: Option<TraceId>,
}

fn one_request(
    client: &mut Client,
    v: &TargetVariant,
    id: u64,
    shifted: bool,
) -> (Outcome, Option<u64>, u32, Option<TraceId>) {
    let image = match (&v.shifted, shifted) {
        (Some(img), true) => img,
        _ => &v.image,
    };
    match client.post_infer(&v.key, id, image) {
        Ok(InferOutcome::Ok(resp)) => (Outcome::Ok, None, resp.bits, resp.trace),
        Ok(InferOutcome::Rejected { retry_after_ms }) => {
            (Outcome::Rejected, Some(retry_after_ms), 0, None)
        }
        Ok(InferOutcome::Failed { .. }) => (Outcome::Failed, None, 0, None),
        Err(_) => (Outcome::Dropped, None, 0, None),
    }
}

/// Best-effort snapshot of the server's stage-latency attribution (the
/// JSON `/metrics` endpoint's `"stages"` object).
fn fetch_stages(cfg: &LoadgenConfig) -> Option<Json> {
    let mut client = Client::new(&cfg.target);
    let parts = client.get("/metrics").ok()?;
    if parts.status != 200 {
        return None;
    }
    let j = Json::parse(std::str::from_utf8(&parts.body).ok()?).ok()?;
    j.get("stages").cloned()
}

/// Run the configured load against the target.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport, String> {
    let targets = discover(cfg)?;
    let n_targets = targets.len();
    let targets = std::sync::Arc::new(targets);
    let t0 = Instant::now();
    let t_end = t0 + cfg.duration;
    let concurrency = cfg.concurrency.max(1);
    let mut joins = Vec::with_capacity(concurrency);
    for t in 0..concurrency {
        let targets = std::sync::Arc::clone(&targets);
        let cfg = cfg.clone();
        joins.push(std::thread::spawn(move || -> Vec<Rec> {
            let mut client = Client::new(&cfg.target);
            let mut recs: Vec<Rec> = Vec::new();
            let shift_at = cfg.shift.map(|s| t0 + s.at);
            match cfg.mode {
                LoadMode::Closed => {
                    let mut seq = 0u64;
                    while Instant::now() < t_end {
                        let vi = (t + seq as usize) % targets.len();
                        let id = t as u64 * 1_000_000_000 + seq;
                        let sent_at = Instant::now();
                        let shifted = shift_at.map_or(false, |at| sent_at >= at);
                        let (outcome, retry_ms, bits, trace) =
                            one_request(&mut client, &targets[vi], id, shifted);
                        recs.push(Rec {
                            variant: vi,
                            outcome,
                            us: sent_at.elapsed().as_micros() as f32,
                            bits,
                            trace,
                        });
                        if let Some(ms) = retry_ms {
                            let nap = Duration::from_millis(ms).min(cfg.backoff_cap);
                            if !nap.is_zero() {
                                std::thread::sleep(nap);
                            }
                        }
                        seq += 1;
                    }
                }
                LoadMode::Open { rps } => {
                    let rps = rps.max(0.001);
                    // Worker t owns schedule slots t, t+C, t+2C, ...
                    let mut k = t as u64;
                    loop {
                        let sched = t0 + Duration::from_secs_f64(k as f64 / rps);
                        if sched >= t_end {
                            break;
                        }
                        let now = Instant::now();
                        if sched > now {
                            std::thread::sleep(sched - now);
                        }
                        let vi = (k as usize) % targets.len();
                        let shifted = shift_at.map_or(false, |at| Instant::now() >= at);
                        let (outcome, _, bits, trace) =
                            one_request(&mut client, &targets[vi], k, shifted);
                        // Latency from the *schedule*, not the send.
                        recs.push(Rec {
                            variant: vi,
                            outcome,
                            us: sched.elapsed().as_micros() as f32,
                            bits,
                            trace,
                        });
                        k += concurrency as u64;
                    }
                }
            }
            recs
        }));
    }
    let mut all: Vec<Rec> = Vec::new();
    for j in joins {
        all.extend(j.join().map_err(|_| "load worker panicked".to_string())?);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let aggregate = |wire: &str, recs: &[&Rec]| -> VariantReport {
        let mut r = VariantReport {
            wire: wire.to_string(),
            sent: recs.len() as u64,
            ok: 0,
            rejected: 0,
            failed: 0,
            dropped: 0,
            mean_us: 0.0,
            p50_us: 0.0,
            p95_us: 0.0,
            p99_us: 0.0,
            served_bits: std::collections::BTreeMap::new(),
            traced: 0,
            trace_ids: Vec::new(),
        };
        let mut ok_us: Vec<f32> = Vec::new();
        for rec in recs {
            match rec.outcome {
                Outcome::Ok => {
                    r.ok += 1;
                    ok_us.push(rec.us);
                    *r.served_bits.entry(rec.bits).or_insert(0) += 1;
                    if let Some(t) = rec.trace {
                        r.traced += 1;
                        if r.trace_ids.len() < TRACE_ID_SAMPLE {
                            r.trace_ids.push(t.to_string());
                        }
                    }
                }
                Outcome::Rejected => r.rejected += 1,
                Outcome::Failed => r.failed += 1,
                Outcome::Dropped => r.dropped += 1,
            }
        }
        r.mean_us = stats::mean(&ok_us);
        r.p50_us = stats::percentile(&ok_us, 50.0);
        r.p95_us = stats::percentile(&ok_us, 95.0);
        r.p99_us = stats::percentile(&ok_us, 99.0);
        r
    };
    let total = aggregate("all", &all.iter().collect::<Vec<_>>());
    let per_variant = (0..n_targets)
        .map(|vi| {
            let recs: Vec<&Rec> = all.iter().filter(|r| r.variant == vi).collect();
            aggregate(&targets[vi].wire, &recs)
        })
        .collect();
    let (mode, offered_rps) = match cfg.mode {
        LoadMode::Open { rps } => ("open".to_string(), Some(rps)),
        LoadMode::Closed => ("closed".to_string(), None),
    };
    Ok(LoadReport {
        mode,
        offered_rps,
        concurrency,
        duration_s: wall_s,
        achieved_rps: if wall_s > 0.0 { total.ok as f64 / wall_s } else { 0.0 },
        shift: cfg.shift.map(|s| s.display()),
        total,
        per_variant,
        stages: fetch_stages(cfg),
    })
}

/// Overload-sweep configuration (`pdq loadgen --sweep`).
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Target / concurrency / variant filter / seed; `mode` and
    /// `duration` are overridden per step.
    pub base: LoadgenConfig,
    /// The 1× baseline in requests per second; 0 = measure it first with
    /// a closed-loop capacity probe of one `step_duration`.
    pub base_rps: f64,
    /// Offered-load multipliers, one sweep step each.
    pub multipliers: Vec<f64>,
    /// Wall-clock length of each step (and of the capacity probe).
    pub step_duration: Duration,
    /// Images per variant for the unloaded rung-accuracy pass.
    pub accuracy_images: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            base: LoadgenConfig::default(),
            base_rps: 0.0,
            multipliers: vec![1.0, 2.0, 4.0, 6.0, 8.0, 10.0],
            step_duration: Duration::from_secs(2),
            accuracy_images: 16,
        }
    }
}

/// One step of the overload sweep.
#[derive(Clone, Debug)]
pub struct SweepStep {
    pub multiplier: f64,
    pub offered_rps: f64,
    pub achieved_rps: f64,
    /// The step's aggregate traffic row (includes the served-bits
    /// histogram — the degradation signature).
    pub total: VariantReport,
}

/// One quantized variant's unloaded fidelity row.
#[derive(Clone, Debug)]
pub struct RungReport {
    pub wire: String,
    /// Effective precision (8/4/2 int8 rungs; fake-quant reports 8).
    pub bits: u32,
    /// Fraction of eval images whose top-1 class matches the model's fp32
    /// variant, measured over the wire.
    pub top1_agreement_fp32: f32,
    /// Mean server-side latency over the eval images (the response
    /// preamble's `latency_us`).
    pub mean_server_us: f32,
}

/// The degradation-curve report (`BENCH_degrade.json`,
/// schema `pdq-degrade-v1`).
#[derive(Clone, Debug)]
pub struct DegradeReport {
    pub base_rps: f64,
    pub concurrency: usize,
    pub step_duration_s: f64,
    pub steps: Vec<SweepStep>,
    pub rungs: Vec<RungReport>,
}

impl DegradeReport {
    pub fn to_json(&self) -> Json {
        let mut cfg = Json::obj();
        cfg.set("base_rps", self.base_rps)
            .set("concurrency", self.concurrency)
            .set("step_duration_s", self.step_duration_s);
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                let shed = if s.total.sent > 0 {
                    s.total.rejected as f64 / s.total.sent as f64
                } else {
                    0.0
                };
                let mut o = Json::obj();
                o.set("multiplier", s.multiplier)
                    .set("offered_rps", s.offered_rps)
                    .set("achieved_rps", s.achieved_rps)
                    .set("shed_rate", shed)
                    .set("traffic", s.total.to_json());
                o
            })
            .collect();
        let rungs: Vec<Json> = self
            .rungs
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("variant", r.wire.as_str())
                    .set("bits", r.bits as u64)
                    .set("top1_agreement_fp32", r.top1_agreement_fp32)
                    .set("mean_server_us", r.mean_server_us);
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("schema", "pdq-degrade-v1")
            .set("config", cfg)
            .set("steps", Json::Arr(steps))
            .set("rungs", Json::Arr(rungs));
        o
    }

    /// Write the JSON report (`BENCH_degrade.json`).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }
}

fn top1(outputs: &[Tensor<f32>]) -> usize {
    let Some(first) = outputs.first() else { return 0 };
    let data = first.data();
    let mut best = 0;
    for (i, &x) in data.iter().enumerate() {
        if x > data[best] {
            best = i;
        }
    }
    best
}

/// Unloaded fidelity pass: every quantized variant's top-1 agreement vs
/// its model's fp32 variant, over the wire, on seeded-noise eval images
/// (the same images per model, so the comparison is paired). Variants of
/// models without an fp32 reference are skipped. Ignores the config's
/// variant filter — the rung rows are only meaningful against the full
/// catalog.
fn rung_accuracy(cfg: &LoadgenConfig, images: usize) -> Result<Vec<RungReport>, String> {
    let all = LoadgenConfig { variants: Vec::new(), models: Vec::new(), ..cfg.clone() };
    let targets = discover(&all)?;
    let mut client = Client::new(&cfg.target);
    let mut preds: Vec<(Vec<usize>, f32)> = Vec::with_capacity(targets.len());
    for v in &targets {
        let mut tops = Vec::with_capacity(images);
        let mut lat_sum = 0.0f64;
        for i in 0..images {
            let mut rng = Pcg32::new(cfg.seed ^ 0xACC0_0000 ^ i as u64);
            let shape = v.image.shape().clone();
            let data: Vec<f32> = (0..shape.numel()).map(|_| rng.uniform()).collect();
            let img = Tensor::from_vec(shape, data);
            match client.post_infer_retrying(&v.key, i as u64, &img) {
                Ok(InferOutcome::Ok(resp)) => {
                    lat_sum += resp.latency_us as f64;
                    tops.push(top1(&resp.outputs));
                }
                Ok(_) => {
                    return Err(format!(
                        "accuracy pass: {} refused a request on an unloaded server",
                        v.wire
                    ))
                }
                Err(e) => return Err(format!("accuracy pass: {}: {e}", v.wire)),
            }
        }
        let mean = if images > 0 { (lat_sum / images as f64) as f32 } else { 0.0 };
        preds.push((tops, mean));
    }
    let mut rows = Vec::new();
    for (i, v) in targets.iter().enumerate() {
        let bits = v.key.spec.precision_bits();
        if bits >= 32 {
            continue;
        }
        let Some(refi) = targets
            .iter()
            .position(|t| t.key.model == v.key.model && t.key.spec.precision_bits() >= 32)
        else {
            continue;
        };
        let matches = preds[i].0.iter().zip(&preds[refi].0).filter(|(a, b)| a == b).count();
        rows.push(RungReport {
            wire: v.wire.clone(),
            bits,
            top1_agreement_fp32: if images > 0 { matches as f32 / images as f32 } else { 0.0 },
            mean_server_us: preds[i].1,
        });
    }
    Ok(rows)
}

/// Run the full overload sweep: rung-fidelity pass, capacity probe (when
/// no baseline was given), then one open-loop step per multiplier.
pub fn run_sweep(cfg: &SweepConfig) -> Result<DegradeReport, String> {
    let rungs = rung_accuracy(&cfg.base, cfg.accuracy_images)?;
    let base_rps = if cfg.base_rps > 0.0 {
        cfg.base_rps
    } else {
        let probe = LoadgenConfig {
            mode: LoadMode::Closed,
            duration: cfg.step_duration,
            ..cfg.base.clone()
        };
        run(&probe)?.achieved_rps.max(1.0)
    };
    let mut steps = Vec::with_capacity(cfg.multipliers.len());
    for &mult in &cfg.multipliers {
        let rps = base_rps * mult;
        let step = LoadgenConfig {
            mode: LoadMode::Open { rps },
            duration: cfg.step_duration,
            ..cfg.base.clone()
        };
        let rep = run(&step)?;
        steps.push(SweepStep {
            multiplier: mult,
            offered_rps: rps,
            achieved_rps: rep.achieved_rps,
            total: rep.total,
        });
    }
    Ok(DegradeReport {
        base_rps,
        concurrency: cfg.base.concurrency.max(1),
        step_duration_s: cfg.step_duration.as_secs_f64(),
        steps,
        rungs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let v = VariantReport {
            wire: "m|fp32".into(),
            sent: 10,
            ok: 8,
            rejected: 2,
            failed: 0,
            dropped: 0,
            mean_us: 100.0,
            p50_us: 90.0,
            p95_us: 200.0,
            p99_us: 300.0,
            served_bits: [(8u32, 6u64), (4, 2)].into_iter().collect(),
            traced: 6,
            trace_ids: vec!["00000000deadbeef".into()],
        };
        let mut stages = Json::obj();
        stages.set("queue", 12.0).set("execute", 340.0);
        let report = LoadReport {
            mode: "open".into(),
            offered_rps: Some(50.0),
            concurrency: 4,
            duration_s: 2.0,
            achieved_rps: 4.0,
            shift: Some("contrast:5@2".into()),
            total: v.clone(),
            per_variant: vec![v],
            stages: Some(stages),
        };
        let j = report.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("pdq-serving-v2"));
        assert_eq!(j.get("config").unwrap().get("mode").unwrap().as_str(), Some("open"));
        assert_eq!(
            j.get("config").unwrap().get("shift").unwrap().as_str(),
            Some("contrast:5@2")
        );
        let agg = j.get("aggregate").unwrap();
        assert_eq!(agg.get("rejected").unwrap().as_usize(), Some(2));
        assert!((agg.get("reject_rate").unwrap().as_f64().unwrap() - 0.2).abs() < 1e-9);
        assert_eq!(agg.get("served_bits").unwrap().get("8").unwrap().as_usize(), Some(6));
        assert_eq!(agg.get("served_bits").unwrap().get("4").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("per_variant").unwrap().as_arr().unwrap().len(), 1);
        // v2 additions: flight-recorder tie-in + server stage snapshot.
        assert_eq!(agg.get("traced").unwrap().as_usize(), Some(6));
        assert_eq!(
            agg.get("trace_ids").unwrap().as_arr().unwrap()[0].as_str(),
            Some("00000000deadbeef")
        );
        assert_eq!(j.get("stages").unwrap().get("execute").unwrap().as_f64(), Some(340.0));
    }

    #[test]
    fn degrade_report_json_shape() {
        let total = VariantReport {
            wire: "all".into(),
            sent: 100,
            ok: 70,
            rejected: 30,
            failed: 0,
            dropped: 0,
            mean_us: 500.0,
            p50_us: 400.0,
            p95_us: 900.0,
            p99_us: 1200.0,
            served_bits: [(8u32, 40u64), (4, 30)].into_iter().collect(),
            traced: 0,
            trace_ids: Vec::new(),
        };
        let report = DegradeReport {
            base_rps: 50.0,
            concurrency: 4,
            step_duration_s: 2.0,
            steps: vec![SweepStep {
                multiplier: 4.0,
                offered_rps: 200.0,
                achieved_rps: 140.0,
                total,
            }],
            rungs: vec![RungReport {
                wire: "m|int8-static-t@4".into(),
                bits: 4,
                top1_agreement_fp32: 0.875,
                mean_server_us: 420.0,
            }],
        };
        let j = report.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("pdq-degrade-v1"));
        assert_eq!(j.get("config").unwrap().get("base_rps").unwrap().as_f64(), Some(50.0));
        let steps = j.get("steps").unwrap().as_arr().unwrap();
        assert_eq!(steps.len(), 1);
        assert!((steps[0].get("shed_rate").unwrap().as_f64().unwrap() - 0.3).abs() < 1e-9);
        let hist = steps[0].get("traffic").unwrap().get("served_bits").unwrap();
        assert_eq!(hist.get("4").unwrap().as_usize(), Some(30));
        let rungs = j.get("rungs").unwrap().as_arr().unwrap();
        assert_eq!(rungs[0].get("bits").unwrap().as_usize(), Some(4));
        assert!(
            (rungs[0].get("top1_agreement_fp32").unwrap().as_f64().unwrap() - 0.875).abs()
                < 1e-6
        );
    }

    #[test]
    fn shift_spec_grammar() {
        let s = ShiftSpec::parse("contrast:5@2").unwrap();
        assert_eq!(s.corruption, Corruption::Contrast);
        assert_eq!(s.severity, 5);
        assert_eq!(s.at, Duration::from_secs(2));
        assert_eq!(s.display(), "contrast:5@2");
        let f = ShiftSpec::parse("white_noise:3@1.5").unwrap();
        assert_eq!(f.at, Duration::from_secs_f64(1.5));
        for bad in [
            "contrast",
            "contrast@2",
            "contrast:9@2",
            "contrast:0@2",
            "fog:3@2",
            "contrast:5@-1",
            "contrast:5@nan",
        ] {
            assert!(ShiftSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    // Socket-level loadgen runs (including --shift against an adaptive
    // server) are covered by rust/tests/serving_http.rs /
    // rust/tests/adapt_loop.rs and the CI smoke steps.
}
