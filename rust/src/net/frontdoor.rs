//! The HTTP front door: a `TcpListener` acceptor feeding a fixed connection
//! pool, routing onto the coordinator.
//!
//! Endpoints:
//! - `POST /v1/infer` — binary tensor body ([`crate::net::wire`]); admitted
//!   through [`Server::try_submit_graceful`], which under precision
//!   brownout (`--brownout`) walks the int8 variant's 8/4/2-bit rung
//!   ladder before ever shedding. The served precision rides back in the
//!   response preamble (`"bits"`) and the `X-PDQ-Bits` header. Only once
//!   the ladder is exhausted (or brownout is off and the variant is at its
//!   in-flight limit) is the request shed with `429` + a load-proportional
//!   `Retry-After` (queue depth ÷ drain rate).
//! - `GET /v1/variants` — the served (variant, input shape) catalog.
//! - `GET /v1/models` / `POST /v1/models` / `DELETE /v1/models/{model}` —
//!   the model zoo. POST hot-loads a `pdq-artifact-v1` menu (body is
//!   either JSON `{"path": "…"}` or the raw artifact bytes); DELETE
//!   unloads one model after its in-flight requests finish (pinned
//!   startup models refuse with `403`). Loading past `--max-models`
//!   evicts the least-recently-used unpinned model.
//! - `GET /v1/drift` — per-variant drift/epoch/recalibration status
//!   (404 unless the server was started with adaptation, `--adapt`).
//! - `GET /v1/slo[?budget_us=&q=&variant=]` — the per-variant SLO budget
//!   ledger (schema `pdq-slo-v1`): each variant's p99 against the
//!   configured budget, decomposed into queue/execute/serialize stage
//!   shares from the exact stage histograms, plus the autopilot's live
//!   knob positions and bounded decision ring when `--autopilot` armed
//!   the controller. The same ledger rides `GET /metrics?format=prometheus`
//!   as `pdq_slo_budget_burn{variant,stage}` gauges.
//! - `POST /v1/recalibrate[?variant=<wire>]` — manual shadow
//!   recalibration trigger (404 without adaptation).
//! - `GET /healthz` — liveness (+ `"draining"` once shutdown began).
//! - `GET /metrics` — JSON; `?format=prometheus` for text exposition
//!   (includes per-variant breakdowns, per-stage latency histograms and,
//!   with adaptation, drift/epoch/recalibration gauges).
//! - `GET /v1/traces[?id=<hex>]` — the flight recorder's ring of recent +
//!   anomalous request traces (404 unless serving with `--trace`). With
//!   tracing armed every `/v1/infer` request carries a trace ID — accepted
//!   from the `X-PDQ-Trace` header or the wire preamble's `"trace"` field,
//!   else minted — echoed back in both, with per-stage spans
//!   (`accept → … → serialize`) and, on int8 variants, per-node kernel
//!   spans. Disarmed (the default), responses are byte-identical to
//!   pre-tracing builds and the hot path allocates nothing for tracing.
//!   `?format=otlp` renders the same rings as one OTLP/JSON
//!   `resourceSpans` document ([`crate::obs::otlp`]), including the
//!   zoo's `zoo.load:…`/`zoo.unload:…` and the adaptation loop's
//!   `adapt.epoch_swap:…` lifecycle spans.
//!
//! Graceful drain (SIGTERM via [`crate::net::signal`], or
//! [`FrontDoor::shutdown`]): (1) the shutdown flag stops the accept loop
//! and tells keep-alive handlers to close after their current request;
//! (2) the connection pool joins, which drains every accepted connection —
//! each in-flight request still receives its HTTP response; (3) only then
//! does the coordinator drain, executing everything queued and joining the
//! workers. Ordering guarantees every admitted request is answered before
//! any worker exits.
//!
//! The accept loop uses a nonblocking listener polled at 5 ms: accepted
//! sockets are handed off immediately under load, and the loop notices the
//! shutdown flag without needing a self-connect wakeup.
//!
//! Hostile-client defenses (slowloris and friends): a max-connection cap
//! answered with `503` + `Retry-After` before any parsing happens, and
//! per-connection deadlines split by request *stage* — a peer trickling
//! header bytes gets [`HEAD_TICKS_MAX`] ticks, one mid-body gets
//! [`BODY_TICKS_MAX`], and an idle keep-alive connection
//! [`IDLE_TICKS_MAX`]. Malformed input is answered, counted in the
//! metrics reject-reason breakdown, and the connection closed.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::{Server, SubmitError, ZooError};
use crate::engine::EngineError;
use crate::net::http::{
    HttpError, HttpRequest, HttpResponse, ReadOutcome, RequestReader, Stage,
    DEFAULT_MAX_BODY_BYTES,
};
use crate::net::signal;
use crate::net::threadpool::ThreadPool;
use crate::net::wire;
use crate::obs::trace::Stage as TraceStage;
use crate::obs::{FlightRecorder, TraceHandle, TraceId, TraceOutcome};
use crate::util::json::Json;

/// Front-door configuration.
#[derive(Clone, Debug)]
pub struct FrontDoorConfig {
    /// Bind address; port 0 picks an ephemeral port.
    pub addr: String,
    /// Connection-handler pool size — the hard ceiling on concurrently
    /// served HTTP requests (admission bounds per-variant depth beneath it).
    pub conn_threads: usize,
    pub max_body_bytes: usize,
    /// How long a handler waits for the coordinator's reply before `504`.
    pub response_timeout: Duration,
    /// Cap on concurrently accepted connections (handled + queued for the
    /// pool). Excess connections get an immediate `503` + `Retry-After`
    /// so a connection flood cannot queue unboundedly. 0 = unlimited.
    pub max_connections: usize,
    /// Arm the flight recorder (`--trace`): every `/v1/infer` request gets
    /// a trace ID, per-stage spans, and a `GET /v1/traces` entry. Off by
    /// default — disarmed serving is byte-identical on the wire and
    /// allocation-free on the hot path.
    pub trace: bool,
    /// Continuous profiling: trace 1 in N `/v1/infer` requests (full
    /// per-stage + kernel spans into the recorder) *without* `--trace`.
    /// 0 disables. Non-sampled requests take the exact disarmed hot path —
    /// bit-identical responses, no trace allocation.
    pub profile_every: usize,
    /// Deterministic phase for the 1-in-N sampler: request counter values
    /// congruent to `profile_seed % profile_every` are sampled, so a
    /// seeded workload replays the same sampled set.
    pub profile_seed: u64,
    /// Default p99 budget for the `/v1/slo` ledger, µs (`--slo-budget-ms`;
    /// a `?budget_us=` query overrides per request).
    pub slo_budget_us: u64,
}

impl Default for FrontDoorConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            conn_threads: 16,
            max_body_bytes: DEFAULT_MAX_BODY_BYTES,
            response_timeout: Duration::from_secs(30),
            max_connections: 256,
            trace: false,
            profile_every: 0,
            profile_seed: 0,
            slo_budget_us: crate::obs::slo::DEFAULT_BUDGET_US,
        }
    }
}

/// Socket read-timeout tick; the granularity at which connection handlers
/// poll the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(500);
/// Keep-alive idle budget (ticks) before a silent connection is closed.
const IDLE_TICKS_MAX: u32 = 20;
/// Budget (ticks) for a peer to deliver a request *head*. Heads are tiny;
/// only a slowloris client needs more than 5 s of ticks, so this is the
/// short leash.
const HEAD_TICKS_MAX: u32 = 10;
/// Budget (ticks) for a peer to finish a request *body* once the head is
/// in — longer, because honest clients upload multi-MB tensor bodies.
const BODY_TICKS_MAX: u32 = 20;

struct Ctx {
    server: Arc<Server>,
    shutdown: AtomicBool,
    started: Instant,
    max_body: usize,
    response_timeout: Duration,
    /// Live connection count (accepted, not yet closed).
    conns: AtomicUsize,
    max_conns: usize,
    /// Flight-recorder arming ([`FrontDoorConfig::trace`]).
    trace: bool,
    /// Continuous-profiling stride ([`FrontDoorConfig::profile_every`]).
    profile_every: usize,
    profile_seed: u64,
    /// Monotone `/v1/infer` counter driving the 1-in-N sampler.
    infer_seq: AtomicU64,
    /// Default `/v1/slo` budget, µs.
    slo_budget_us: u64,
    recorder: Arc<FlightRecorder>,
}

/// RAII decrement of [`Ctx::conns`] — however a handler exits (clean
/// close, parse error, panic unwinding through the pool), the slot frees.
struct ConnGuard(Arc<Ctx>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The running front door.
pub struct FrontDoor {
    ctx: Arc<Ctx>,
    local_addr: SocketAddr,
    accept_handle: Option<JoinHandle<()>>,
}

impl FrontDoor {
    /// Bind and start accepting on top of a running coordinator.
    pub fn start(server: Arc<Server>, cfg: FrontDoorConfig) -> std::io::Result<FrontDoor> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let recorder = Arc::new(FlightRecorder::default());
        // Arm the SLO autopilot (no-op unless the server was configured
        // with it): its retunes land in this recorder as lifecycle traces.
        server.spawn_autopilot(Arc::clone(&recorder));
        let ctx = Arc::new(Ctx {
            server,
            shutdown: AtomicBool::new(false),
            started: Instant::now(),
            max_body: cfg.max_body_bytes,
            response_timeout: cfg.response_timeout,
            conns: AtomicUsize::new(0),
            max_conns: cfg.max_connections,
            trace: cfg.trace,
            profile_every: cfg.profile_every,
            profile_seed: cfg.profile_seed,
            infer_seq: AtomicU64::new(0),
            slo_budget_us: cfg.slo_budget_us.max(1),
            recorder,
        });
        let pool = ThreadPool::new("pdq-http", cfg.conn_threads);
        let accept_ctx = Arc::clone(&ctx);
        let accept_handle = std::thread::Builder::new()
            .name("pdq-accept".into())
            .spawn(move || accept_loop(listener, pool, accept_ctx))?;
        Ok(FrontDoor { ctx, local_addr, accept_handle: Some(accept_handle) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.local_addr)
    }

    /// The flight recorder backing `GET /v1/traces` (empty unless
    /// [`FrontDoorConfig::trace`] armed it).
    pub fn recorder(&self) -> Arc<FlightRecorder> {
        Arc::clone(&self.ctx.recorder)
    }

    /// Idempotent graceful drain (see module docs for the ordering).
    fn begin_drain(&mut self) {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join(); // joins the connection pool too
        }
        self.ctx.server.drain();
    }

    /// Drain now and return the final metrics.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.begin_drain();
        self.ctx.server.metrics_arc()
    }

    /// Block until shutdown is requested — SIGTERM/SIGINT (when
    /// [`signal::install_term_handler`] was called) or a programmatic
    /// [`signal::request_term`] — then drain and return the final metrics.
    pub fn wait(mut self) -> Arc<Metrics> {
        while !self.ctx.shutdown.load(Ordering::SeqCst) && !signal::term_requested() {
            std::thread::sleep(Duration::from_millis(100));
        }
        self.begin_drain();
        self.ctx.server.metrics_arc()
    }
}

impl Drop for FrontDoor {
    fn drop(&mut self) {
        self.begin_drain();
    }
}

fn accept_loop(listener: TcpListener, pool: ThreadPool, ctx: Arc<Ctx>) {
    while !ctx.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let prev = ctx.conns.fetch_add(1, Ordering::SeqCst);
                if ctx.max_conns > 0 && prev >= ctx.max_conns {
                    // Flood defense: answer at the door without parsing a
                    // byte, so a connection storm can't queue unboundedly
                    // behind the worker pool.
                    ctx.conns.fetch_sub(1, Ordering::SeqCst);
                    ctx.server.metrics().on_connection_cap();
                    let mut s = stream;
                    let _ = s.set_nonblocking(false);
                    let _ = HttpResponse::error(503, "connection limit reached")
                        .header("Retry-After", "1")
                        .header("Connection", "close")
                        .write_to(&mut s);
                    continue;
                }
                let guard = ConnGuard(Arc::clone(&ctx));
                let conn_ctx = Arc::clone(&ctx);
                let job = move || {
                    let _guard = guard;
                    handle_connection(stream, conn_ctx);
                };
                if pool.execute(job).is_err() {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            // Transient accept errors (EMFILE, ECONNABORTED): back off.
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    // Every accepted-but-unhandled connection still gets served.
    pool.join();
}

fn handle_connection(stream: TcpStream, ctx: Arc<Ctx>) {
    let _ = stream.set_nodelay(true);
    // Some platforms let accepted sockets inherit the listener's
    // O_NONBLOCK; force blocking so the read-timeout tick is the only
    // WouldBlock source (a nonblocking read would spin the idle budget).
    if stream.set_nonblocking(false).is_err()
        || stream.set_read_timeout(Some(READ_TICK)).is_err()
    {
        return;
    }
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = RequestReader::new(read_half, ctx.max_body);
    let mut out = stream;
    let mut idle_ticks = 0u32;
    let mut head_ticks = 0u32;
    let mut body_ticks = 0u32;
    // The accept window: from the start of the first read call that saw
    // request bytes (mid-request ticks pin it) to the request being fully
    // read. Idle keep-alive ticks never count — they reset nothing but
    // contribute no window — though a request arriving mid-tick can carry
    // up to one READ_TICK of pre-byte slack.
    let mut accept_start: Option<Instant> = None;
    loop {
        let tick_start = Instant::now();
        match reader.read_request() {
            Ok(ReadOutcome::Request(req)) => {
                idle_ticks = 0;
                head_ticks = 0;
                body_ticks = 0;
                let accepted = (accept_start.take().unwrap_or(tick_start), Instant::now());
                let close = req.wants_close() || ctx.shutdown.load(Ordering::SeqCst);
                let resp = route_request(&req, &ctx, accepted)
                    .header("Connection", if close { "close" } else { "keep-alive" });
                if resp.write_to(&mut out).is_err() || close {
                    return;
                }
            }
            Ok(ReadOutcome::Eof) => return,
            Ok(ReadOutcome::Timeout { idle: true }) => {
                idle_ticks += 1;
                if ctx.shutdown.load(Ordering::SeqCst) || idle_ticks > IDLE_TICKS_MAX {
                    return;
                }
            }
            Ok(ReadOutcome::Timeout { idle: false }) => {
                accept_start.get_or_insert(tick_start);
                // Peer is mid-request: keep reading (even during drain — an
                // accepted request gets its response) up to a stage-scoped
                // budget. Trickling header bytes (slowloris) gets the short
                // head leash; an in-flight body upload gets the longer one.
                let over = match reader.stage() {
                    Stage::Body => {
                        body_ticks += 1;
                        body_ticks > BODY_TICKS_MAX
                    }
                    _ => {
                        head_ticks += 1;
                        head_ticks > HEAD_TICKS_MAX
                    }
                };
                if over {
                    let _ = HttpResponse::error(408, "timed out mid-request")
                        .header("Connection", "close")
                        .write_to(&mut out);
                    return;
                }
            }
            Err(e) => {
                match &e {
                    HttpError::BadChunk(_) => ctx.server.metrics().on_bad_chunk(),
                    HttpError::BadRequest(_) | HttpError::Unsupported(_) => {
                        ctx.server.metrics().on_parse_error()
                    }
                    HttpError::TooLarge(_) => ctx.server.metrics().on_oversized(),
                    // Abrupt hangups aren't malformed input.
                    HttpError::UnexpectedEof | HttpError::Io(_) => {}
                }
                if let Some(status) = e.status() {
                    let _ = HttpResponse::error(status, &e.to_string())
                        .header("Connection", "close")
                        .write_to(&mut out);
                }
                return;
            }
        }
    }
}

fn route_request(
    req: &HttpRequest,
    ctx: &Ctx,
    accepted: (Instant, Instant),
) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => healthz(ctx),
        ("GET", "/metrics") => metrics(req, ctx),
        ("GET", "/v1/variants") => variants(ctx),
        ("GET", "/v1/models") => models_get(ctx),
        ("POST", "/v1/models") => models_post(req, ctx),
        ("DELETE", p) if p.starts_with("/v1/models/") => models_delete(req, ctx),
        ("GET", "/v1/drift") => drift(ctx),
        ("GET", "/v1/slo") => slo(req, ctx),
        ("GET", "/v1/traces") => traces(req, ctx),
        ("POST", "/v1/recalibrate") => recalibrate(req, ctx),
        ("POST", "/v1/infer") => infer(req, ctx, accepted),
        ("GET", "/v1/infer") => HttpResponse::error(405, "use POST /v1/infer"),
        ("GET", "/v1/recalibrate") => {
            HttpResponse::error(405, "use POST /v1/recalibrate")
        }
        _ => HttpResponse::error(404, &format!("no route {} {}", req.method, req.path)),
    }
}

fn drift(ctx: &Ctx) -> HttpResponse {
    let Some(manager) = ctx.server.adapt() else {
        return HttpResponse::error(404, "adaptation disabled (start the server with --adapt)");
    };
    let list: Vec<Json> = manager
        .status()
        .iter()
        .map(|s| {
            let mut v = Json::obj();
            let per_node: Vec<Json> = s
                .per_node
                .iter()
                .map(|n| {
                    let mut o = Json::obj();
                    o.set("node", n.node)
                        .set("score", n.score as f64)
                        .set("clip_excess", n.clip_excess as f64);
                    o
                })
                .collect();
            v.set("variant", s.key.wire())
                .set("epoch", s.epoch)
                .set("drift", s.drift as f64)
                .set("peak_drift", s.peak_drift as f64)
                .set("drifted", s.drifted)
                .set("max_clip_rate", s.max_clip_rate as f64)
                .set("recalibrations", s.recalibrations)
                .set("window_requests", s.window_requests)
                .set("requests_seen", s.requests_seen)
                .set("reservoir", s.reservoir)
                .set("backend", s.backend)
                .set("per_node", Json::Arr(per_node));
            v
        })
        .collect();
    let mut o = Json::obj();
    o.set("variants", Json::Arr(list))
        .set("threshold", manager.config().drift.threshold as f64)
        .set("cooldown_s", manager.config().policy.cooldown.as_secs_f64());
    HttpResponse::json(200, &o)
}

fn recalibrate(req: &HttpRequest, ctx: &Ctx) -> HttpResponse {
    let Some(manager) = ctx.server.adapt() else {
        return HttpResponse::error(404, "adaptation disabled (start the server with --adapt)");
    };
    let filter = match req.query_param("variant") {
        None => None,
        Some(wire) => match crate::engine::VariantKey::parse_wire(wire) {
            Ok(key) => Some(key),
            Err(e) => return HttpResponse::error(400, &e),
        },
    };
    let t0 = Instant::now();
    let outcomes = manager.recalibrate_now(filter.as_ref());
    if filter.is_some() && outcomes.is_empty() {
        return HttpResponse::error(404, "variant not registered for adaptation");
    }
    if outcomes.iter().any(|o| o.fired) {
        let scope = filter.as_ref().map(|k| k.wire()).unwrap_or_else(|| "all".into());
        commit_lifecycle(ctx, &format!("adapt.epoch_swap:{scope}"), t0);
    }
    let list: Vec<Json> = outcomes
        .iter()
        .map(|o| {
            let mut v = Json::obj();
            v.set("variant", o.key.wire())
                .set("fired", o.fired)
                .set("epoch", o.epoch)
                .set("detail", o.detail.as_str());
            v
        })
        .collect();
    let mut o = Json::obj();
    o.set("outcomes", Json::Arr(list));
    HttpResponse::json(200, &o)
}

/// `GET /v1/slo` — the per-variant SLO budget ledger (`pdq-slo-v1`):
/// each variant's p99 against the budget, decomposed into
/// queue/execute/serialize stage shares from the exact stage histograms,
/// plus the autopilot's live knob positions and decision ring when the
/// controller is armed. Query: `budget_us=`, `q=`, `variant=` (strictly
/// parsed; hostile spellings are 400s, see [`crate::obs::slo::SloQuery`]).
fn slo(req: &HttpRequest, ctx: &Ctx) -> HttpResponse {
    let query = match crate::obs::slo::SloQuery::parse(req.query.as_deref().unwrap_or("")) {
        Ok(q) => q,
        Err(e) => return HttpResponse::error(400, &format!("bad /v1/slo query: {e}")),
    };
    let budget = query.budget_us.unwrap_or(ctx.slo_budget_us);
    let q = query.q.unwrap_or(0.99);
    let mut ledger =
        crate::obs::slo::ledger(&ctx.server.metrics().slo_snapshot(), budget, q);
    if let Some(want) = &query.variant {
        ledger.variants.retain(|v| &v.variant == want);
    }
    let mut o = ledger.to_json();
    let mut ap = Json::obj();
    match ctx.server.autopilot() {
        Some(ctl) => {
            ap.set("enabled", true)
                .set("actions", ctl.actions())
                .set("depth", ctx.server.max_queue_depth())
                .set("deadline_us", ctx.server.live_policy().deadline_us())
                .set("decisions", Json::Arr(ctl.decisions_json()));
        }
        None => {
            ap.set("enabled", false);
        }
    }
    o.set("autopilot", ap);
    HttpResponse::json(200, &o)
}

fn traces(req: &HttpRequest, ctx: &Ctx) -> HttpResponse {
    // Armed by `--trace` or by continuous profiling (`--profile-every` /
    // `--autopilot`): sampled traces are only useful if readable.
    if !ctx.trace && ctx.profile_every == 0 {
        return HttpResponse::error(404, "tracing disabled (start the server with --trace)");
    }
    if req.query_param("format") == Some("otlp") {
        let doc = crate::obs::otlp::traces_to_otlp(&ctx.recorder.snapshot(), "pdq");
        return HttpResponse::json(200, &doc);
    }
    HttpResponse::json(200, &ctx.recorder.to_json(req.query_param("id")))
}

/// Commit a lifecycle trace (`zoo.load:…`, `zoo.unload:…`,
/// `adapt.epoch_swap:…`) covering `[start, now]` to the flight recorder.
/// No-op when tracing is disarmed. Lifecycle traces carry the dotted
/// operation label in the variant slot; the OTLP exporter renders them as
/// `INTERNAL` spans.
fn commit_lifecycle(ctx: &Ctx, op: &str, start: Instant) {
    if !ctx.trace && ctx.profile_every == 0 {
        return;
    }
    let h = TraceHandle::new(TraceId::mint(), start);
    h.set_request(op, 0);
    ctx.recorder.commit(h.finish(Instant::now()), 0.0);
}

/// Map a zoo refusal onto HTTP. Name clashes and a full pinned zoo are
/// conflicts; unknown models don't exist; pinned models may not be
/// unloaded remotely; drain refuses new models like it refuses new work.
fn zoo_error(e: &ZooError) -> HttpResponse {
    let status = match e {
        ZooError::AlreadyLoaded(_) | ZooError::Full { .. } => 409,
        ZooError::UnknownModel(_) => 404,
        ZooError::Pinned(_) => 403,
        ZooError::Draining => 503,
        ZooError::Invalid(_) => 400,
    };
    HttpResponse::error(status, &e.to_string())
}

fn models_get(ctx: &Ctx) -> HttpResponse {
    let list: Vec<Json> = ctx
        .server
        .models()
        .iter()
        .map(|m| {
            let mut v = Json::obj();
            v.set("model", m.name.as_str())
                .set("epoch", m.epoch)
                .set("pinned", m.pinned)
                .set("variants", m.variants)
                .set("last_used", m.last_used);
            v
        })
        .collect();
    let mut o = Json::obj();
    o.set("models", Json::Arr(list)).set("max_models", ctx.server.max_models());
    HttpResponse::json(200, &o)
}

fn models_post(req: &HttpRequest, ctx: &Ctx) -> HttpResponse {
    use crate::artifact::ArtifactEngine;
    let t0 = Instant::now();
    // Raw artifact bytes are self-identifying by magic; anything else must
    // be a JSON body naming a server-local path.
    let loaded = if req.body.starts_with(b"PDQA1") {
        ArtifactEngine::from_bytes(&req.body)
    } else {
        let Ok(text) = std::str::from_utf8(&req.body) else {
            return HttpResponse::error(
                400,
                "body is neither a pdq-artifact-v1 image nor JSON",
            );
        };
        let j = match Json::parse(text) {
            Ok(j) => j,
            Err(e) => return HttpResponse::error(400, &format!("bad JSON body: {e}")),
        };
        let Some(path) = j.get("path").and_then(|p| p.as_str()) else {
            return HttpResponse::error(
                400,
                "JSON body must carry {\"path\": \"...\"} (or POST the raw artifact bytes)",
            );
        };
        ArtifactEngine::load(std::path::Path::new(path))
    };
    let art = match loaded {
        Ok(a) => a,
        // Every artifact defect — bad magic, truncation, checksum, schema —
        // is the caller's fault: typed, never a panic.
        Err(e) => return HttpResponse::error(400, &format!("artifact rejected: {e}")),
    };
    let name = art.manifest().model.clone();
    let epoch = art.manifest().epoch;
    let menu = art.into_menu();
    let variants = menu.len();
    match ctx.server.hot_load(menu, epoch) {
        Ok(evicted) => {
            commit_lifecycle(ctx, &format!("zoo.load:{name}"), t0);
            let mut o = Json::obj();
            o.set("loaded", name.as_str())
                .set("epoch", epoch)
                .set("variants", variants)
                .set(
                    "evicted",
                    Json::Arr(evicted.iter().map(|n| Json::from(n.as_str())).collect()),
                );
            HttpResponse::json(200, &o)
        }
        Err(e) => zoo_error(&e),
    }
}

fn models_delete(req: &HttpRequest, ctx: &Ctx) -> HttpResponse {
    let t0 = Instant::now();
    let name = req.path.trim_start_matches("/v1/models/");
    if name.is_empty() || name.contains('/') {
        return HttpResponse::error(400, "expected DELETE /v1/models/{model}");
    }
    match ctx.server.unload_model(name) {
        Ok(()) => {
            commit_lifecycle(ctx, &format!("zoo.unload:{name}"), t0);
            let mut o = Json::obj();
            o.set("unloaded", name);
            HttpResponse::json(200, &o)
        }
        Err(e) => zoo_error(&e),
    }
}

fn healthz(ctx: &Ctx) -> HttpResponse {
    let draining = ctx.shutdown.load(Ordering::SeqCst);
    let mut o = Json::obj();
    o.set("status", if draining { "draining" } else { "ok" })
        .set("uptime_s", ctx.started.elapsed().as_secs_f64())
        .set("variants", ctx.server.catalog().len());
    HttpResponse::json(200, &o)
}

fn metrics(req: &HttpRequest, ctx: &Ctx) -> HttpResponse {
    if req.query_param("format") == Some("prometheus") {
        let mut body = ctx.server.metrics().to_prometheus();
        body.push_str("# HELP pdq_inflight Admitted requests not yet answered.\n");
        body.push_str("# TYPE pdq_inflight gauge\n");
        for (key, depth) in ctx.server.admission_depths() {
            body.push_str(&format!("pdq_inflight{{variant=\"{}\"}} {depth}\n", key.wire()));
        }
        // The SLO ledger rides along as burn gauges: per variant, one
        // series per tracked stage plus the end-to-end total.
        let ledger = crate::obs::slo::ledger(
            &ctx.server.metrics().slo_snapshot(),
            ctx.slo_budget_us,
            0.99,
        );
        body.push_str(&ledger.to_prometheus_gauges());
        if let Some(manager) = ctx.server.adapt() {
            let status = manager.status();
            body.push_str("# HELP pdq_drift_score Aggregate drift vs the calibration reference.\n");
            body.push_str("# TYPE pdq_drift_score gauge\n");
            for s in &status {
                body.push_str(&format!(
                    "pdq_drift_score{{variant=\"{}\"}} {}\n",
                    s.key.wire(),
                    s.drift
                ));
            }
            body.push_str("# HELP pdq_drift_clip_rate Max per-node live clip rate.\n");
            body.push_str("# TYPE pdq_drift_clip_rate gauge\n");
            for s in &status {
                body.push_str(&format!(
                    "pdq_drift_clip_rate{{variant=\"{}\"}} {}\n",
                    s.key.wire(),
                    s.max_clip_rate
                ));
            }
            body.push_str("# HELP pdq_engine_epoch Current engine generation (swaps bump it).\n");
            body.push_str("# TYPE pdq_engine_epoch gauge\n");
            for s in &status {
                body.push_str(&format!(
                    "pdq_engine_epoch{{variant=\"{}\"}} {}\n",
                    s.key.wire(),
                    s.epoch
                ));
            }
            body.push_str(
                "# HELP pdq_recalibrations_total Completed shadow recalibrations.\n",
            );
            body.push_str("# TYPE pdq_recalibrations_total counter\n");
            for s in &status {
                body.push_str(&format!(
                    "pdq_recalibrations_total{{variant=\"{}\"}} {}\n",
                    s.key.wire(),
                    s.recalibrations
                ));
            }
        }
        HttpResponse::text(200, "text/plain; version=0.0.4", body)
    } else {
        let mut o = ctx.server.metrics().to_json();
        let mut inflight = Json::obj();
        for (key, depth) in ctx.server.admission_depths() {
            inflight.set(&key.wire(), depth);
        }
        o.set("in_flight", inflight).set("max_queue_depth", ctx.server.max_queue_depth());
        HttpResponse::json(200, &o)
    }
}

fn variants(ctx: &Ctx) -> HttpResponse {
    let list: Vec<Json> = ctx
        .server
        .catalog()
        .iter()
        .map(|(key, shape)| {
            let mut v = Json::obj();
            v.set("variant", key.wire()).set("label", key.label()).set(
                "input_shape",
                Json::Arr(shape.dims().iter().map(|&d| Json::Num(d as f64)).collect()),
            );
            v
        })
        .collect();
    let mut o = Json::obj();
    o.set("variants", Json::Arr(list))
        .set("max_queue_depth", ctx.server.max_queue_depth());
    HttpResponse::json(200, &o)
}

/// Load-proportional `Retry-After` in milliseconds: the estimated time for
/// `workers` parallel workers to drain the `depth` requests queued ahead
/// at `latency_us` apiece (the p50 histogram hint), clamped to
/// [1 ms, 5 s]. A cold server with no latency signal yet answers a flat
/// 25 ms so early rejections still spread retries out.
fn retry_after_ms(depth: usize, latency_us: f64, workers: usize) -> u64 {
    let est_ms = if latency_us > 0.0 {
        (latency_us / 1000.0) * depth as f64 / workers.max(1) as f64
    } else {
        25.0
    };
    est_ms.clamp(1.0, 5000.0).ceil() as u64
}

/// The traced request's ID: `X-PDQ-Trace` header first, then the wire
/// preamble's `"trace"` field, else freshly minted.
fn trace_id_for(req: &HttpRequest, wire_trace: Option<TraceId>) -> TraceId {
    req.header("x-pdq-trace")
        .and_then(TraceId::parse)
        .or(wire_trace)
        .unwrap_or_else(TraceId::mint)
}

fn infer(req: &HttpRequest, ctx: &Ctx, accepted: (Instant, Instant)) -> HttpResponse {
    let mx = ctx.server.metrics();
    let us = |a: Instant, b: Instant| b.saturating_duration_since(a).as_secs_f64() * 1e6;
    mx.on_stage_us(TraceStage::Accept, us(accepted.0, accepted.1));
    // Continuous profiling: a deterministic 1-in-N of requests is traced
    // end to end (kernel spans included) with no `--trace` flag. The
    // counter ticks on every infer request so a seeded workload samples
    // the same set on every run; non-sampled requests take the exact
    // disarmed path below (no handle, no header, no allocation).
    let sampled = ctx.profile_every > 0 && {
        let n = ctx.profile_every as u64;
        ctx.infer_seq.fetch_add(1, Ordering::Relaxed) % n == ctx.profile_seed % n
    };
    let armed = ctx.trace || sampled;
    let t_parse0 = accepted.1;
    let wire_req = match wire::decode_infer_request(&req.body) {
        Ok(r) => r,
        Err(e) => {
            // Malformed bodies still leave an anomalous trace when armed —
            // hostile traffic is exactly what an operator wants on record.
            if armed {
                let h = TraceHandle::new(trace_id_for(req, None), accepted.0);
                h.span(TraceStage::Accept, accepted.0, accepted.1);
                h.set_outcome(TraceOutcome::Error);
                ctx.recorder.commit(h.finish(Instant::now()), 0.0);
            }
            return HttpResponse::error(400, &e);
        }
    };
    let t_parse1 = Instant::now();
    mx.on_stage_us(TraceStage::Parse, us(t_parse0, t_parse1));
    let wire_name = wire_req.variant.wire();
    let handle = if armed {
        let h = TraceHandle::new(trace_id_for(req, wire_req.trace), accepted.0);
        h.span(TraceStage::Accept, accepted.0, accepted.1);
        h.span(TraceStage::Parse, t_parse0, t_parse1);
        h.set_request(&wire_name, wire_req.id);
        Some(h)
    } else {
        None
    };
    let native_bits = wire_req.variant.spec.precision_bits();
    // Validate the shape at the boundary so a bad request is refused
    // before it costs a queue slot. (Defense in depth only: if this check
    // is bypassed, the engine returns a typed ShapeMismatch below rather
    // than panicking a worker.)
    let catalog = ctx.server.catalog();
    if let Some((_, want)) = catalog.iter().find(|(k, _)| *k == wire_req.variant) {
        if wire_req.image.shape() != want {
            let resp = HttpResponse::error(
                400,
                &format!("variant expects input shape {want}, got {}", wire_req.image.shape()),
            );
            return finish_trace(ctx, handle, TraceOutcome::Error, resp);
        }
    }
    let t_admit0 = Instant::now();
    let submitted = ctx.server.try_submit_traced(
        wire_req.variant,
        wire_req.id,
        wire_req.image,
        handle.clone(),
    );
    let t_admit1 = Instant::now();
    mx.on_stage_us(TraceStage::Admit, us(t_admit0, t_admit1));
    if let Some(h) = &handle {
        h.span(TraceStage::Admit, t_admit0, t_admit1);
    }
    let (outcome, resp) = match submitted {
        Ok((rx, permit, bits)) => match rx.recv_timeout(ctx.response_timeout) {
            Ok(resp) => {
                let (outcome, status) = match resp.result {
                    Ok(outputs) => {
                        let t_ser0 = Instant::now();
                        let body = wire::encode_infer_response(
                            resp.id,
                            resp.latency.as_micros() as u64,
                            bits,
                            handle.as_ref().map(|h| h.id()),
                            &outputs,
                        );
                        let t_ser1 = Instant::now();
                        // Per-variant form: serialize feeds the variant's
                        // SLO stage histogram as well as the global one.
                        mx.on_serialize_for(
                            &wire_name,
                            t_ser1.saturating_duration_since(t_ser0),
                        );
                        if let Some(h) = &handle {
                            h.span(TraceStage::Serialize, t_ser0, t_ser1);
                            h.set_bits(bits);
                        }
                        let outcome = if bits < native_bits {
                            TraceOutcome::Degraded
                        } else {
                            TraceOutcome::Ok
                        };
                        (
                            outcome,
                            HttpResponse::bytes(200, wire::TENSOR_CONTENT_TYPE, body)
                                .header("X-PDQ-Bits", &bits.to_string()),
                        )
                    }
                    // The library's typed errors map onto the protocol: a
                    // shape mismatch is the *caller's* fault (400), every
                    // other engine failure is ours (500). Workers never
                    // panic on request data, so these are the only shapes
                    // an executed request can come back in.
                    Err(e @ EngineError::ShapeMismatch { .. }) => {
                        (TraceOutcome::Error, HttpResponse::error(400, &e.to_string()))
                    }
                    Err(e) => (TraceOutcome::Error, HttpResponse::error(500, &e.to_string())),
                };
                drop(permit); // slot freed only once the response is in hand
                (outcome, status)
            }
            Err(_) => {
                // The job is still queued/executing even though this client
                // gave up. Freeing the slot now would re-admit new requests
                // on top of the abandoned work, un-bounding the very depth
                // admission bounds — so a reaper holds the permit until the
                // worker actually finishes (or the channel dies at drain).
                std::thread::spawn(move || {
                    let _ = rx.recv();
                    drop(permit);
                });
                (TraceOutcome::Timeout, HttpResponse::error(504, "execution timed out"))
            }
        },
        Err(SubmitError::UnknownVariant(v)) => (
            TraceOutcome::Error,
            HttpResponse::error(404, &format!("unknown variant {v:?}")),
        ),
        Err(SubmitError::Overloaded { depth }) => {
            // Load-proportional retry hint: time to drain the queue ahead,
            // depth × p50 ÷ workers. Histogram walk, not the reservoir
            // sort — the shed path must stay cheap precisely when the
            // server is saturated.
            let p50_us = ctx.server.metrics().latency_p50_hint_us();
            let ms = retry_after_ms(depth, p50_us as f64, ctx.server.workers_per_variant());
            (
                TraceOutcome::Shed,
                HttpResponse::error(429, "variant over its in-flight limit; retry later")
                    .header("Retry-After", &ms.div_ceil(1000).max(1).to_string())
                    .header("X-PDQ-Retry-After-Ms", &ms.to_string()),
            )
        }
        Err(SubmitError::Draining) => {
            (TraceOutcome::Shed, HttpResponse::error(503, "server is draining"))
        }
    };
    finish_trace(ctx, handle, outcome, resp)
}

/// Seal a request's trace — stamp the outcome, echo `X-PDQ-Trace`, and
/// commit to the flight recorder (anomaly-flagged against the live
/// histogram p99). No-op when tracing is disarmed.
fn finish_trace(
    ctx: &Ctx,
    handle: Option<TraceHandle>,
    outcome: TraceOutcome,
    resp: HttpResponse,
) -> HttpResponse {
    let Some(h) = handle else { return resp };
    h.set_outcome(outcome);
    let trace = h.finish(Instant::now());
    let id = trace.id.to_string();
    let p99 = ctx.server.metrics().latency_quantile_hint_us(0.99) as f64;
    ctx.recorder.commit(trace, p99);
    resp.header("X-PDQ-Trace", &id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::engine::{FloatEngine, VariantKey, VariantSpec};
    use crate::nn::Graph;
    use crate::tensor::{Shape, Tensor};

    fn tiny_server() -> Arc<Server> {
        let mut g = Graph::new(Shape::hwc(2, 2, 1));
        let x = g.input();
        let r = g.relu(x);
        g.mark_output(r);
        let key = VariantKey::new("m", VariantSpec::Fp32);
        Arc::new(Server::start(
            vec![(key, Arc::new(FloatEngine::new(Arc::new(g))))],
            ServerConfig::default(),
        ))
    }

    #[test]
    fn boots_serves_basics_and_drains() {
        let fd = FrontDoor::start(tiny_server(), FrontDoorConfig::default()).unwrap();
        let addr = fd.local_addr().to_string();
        let mut client = wire::Client::new(&addr);

        let health = client.get("/healthz").unwrap();
        assert_eq!(health.status, 200);
        let j = Json::parse(std::str::from_utf8(&health.body).unwrap()).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));

        let vars = client.get("/v1/variants").unwrap();
        let j = Json::parse(std::str::from_utf8(&vars.body).unwrap()).unwrap();
        let list = j.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].get("variant").unwrap().as_str(), Some("m|fp32"));

        let infer = {
            let key = VariantKey::new("m", VariantSpec::Fp32);
            let img = Tensor::from_vec(Shape::hwc(2, 2, 1), vec![1.0, -2.0, 3.0, -4.0]);
            client.post_infer(&key, 9, &img).unwrap()
        };
        match infer {
            wire::InferOutcome::Ok(resp) => {
                assert_eq!(resp.id, 9);
                assert_eq!(resp.outputs[0].data(), &[1.0, 0.0, 3.0, 0.0], "relu output");
            }
            _ => panic!("infer must succeed"),
        }

        let missing = client.get("/no/such/route").unwrap();
        assert_eq!(missing.status, 404);

        // Adaptation endpoints 404 on a server started without --adapt
        // (the adaptive paths are covered in rust/tests/adapt_loop.rs).
        let drift = client.get("/v1/drift").unwrap();
        assert_eq!(drift.status, 404);
        let recal = client.request("POST", "/v1/recalibrate", "", &[]).unwrap();
        assert_eq!(recal.status, 404);

        let metrics = fd.shutdown();
        assert_eq!(metrics.responses(), 1);
    }

    #[test]
    fn retry_after_scales_with_queue_depth_and_drain_rate() {
        // 8 queued × 10 ms each through 2 workers → 40 ms to drain.
        assert_eq!(retry_after_ms(8, 10_000.0, 2), 40);
        // Twice the backlog, twice the hint; twice the workers, half.
        assert_eq!(retry_after_ms(16, 10_000.0, 2), 80);
        assert_eq!(retry_after_ms(8, 10_000.0, 4), 20);
        // Cold server (no latency signal): flat 25 ms fallback.
        assert_eq!(retry_after_ms(8, 0.0, 2), 25);
        // Clamped to [1 ms, 5 s]; a zero worker count cannot divide by 0.
        assert_eq!(retry_after_ms(1, 100.0, 4), 1);
        assert_eq!(retry_after_ms(10_000, 100_000.0, 1), 5000);
        assert_eq!(retry_after_ms(4, 10_000.0, 0), 40);
    }

    #[test]
    fn zoo_endpoints_hot_load_and_unload_over_http() {
        let cfg = FrontDoorConfig { trace: true, ..FrontDoorConfig::default() };
        let fd = FrontDoor::start(tiny_server(), cfg).unwrap();
        let addr = fd.local_addr().to_string();
        let mut client = wire::Client::new(&addr);
        let parse = |body: &[u8]| Json::parse(std::str::from_utf8(body).unwrap()).unwrap();

        // The catalog starts with just the pinned startup model.
        let r = client.get("/v1/models").unwrap();
        assert_eq!(r.status, 200);
        let j = parse(&r.body);
        let models = j.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("model").unwrap().as_str(), Some("m"));
        assert_eq!(models[0].get("pinned").unwrap().as_bool(), Some(true));

        // Hot-load a freshly packed artifact by POSTing its raw bytes.
        let model = crate::coordinator::calibrate::demo_model("zoo");
        let opts = crate::artifact::PackOptions {
            epoch: 3,
            calib_size: 4,
            ..crate::artifact::PackOptions::default()
        };
        let bytes = crate::artifact::pack_model(&model, opts).unwrap();
        let r = client
            .request("POST", "/v1/models", "application/octet-stream", &bytes)
            .unwrap();
        assert_eq!(r.status, 200, "load failed: {}", String::from_utf8_lossy(&r.body));
        let j = parse(&r.body);
        assert_eq!(j.get("loaded").unwrap().as_str(), Some("zoo"));
        assert_eq!(j.get("epoch").unwrap().as_f64(), Some(3.0));
        assert!(j.get("variants").unwrap().as_f64().unwrap() >= 1.0);

        // Loading the same name again is a conflict, not a panic.
        let r = client
            .request("POST", "/v1/models", "application/octet-stream", &bytes)
            .unwrap();
        assert_eq!(r.status, 409);

        // The new model's variants join the serving catalog.
        let r = client.get("/v1/variants").unwrap();
        let j = parse(&r.body);
        assert!(j
            .get("variants")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .any(|v| v.get("variant").unwrap().as_str().unwrap().starts_with("zoo|")));

        // Hostile loads are refused with typed 400s.
        let r = client
            .request("POST", "/v1/models", "application/octet-stream", b"PDQA1\n garbage")
            .unwrap();
        assert_eq!(r.status, 400);
        let r = client
            .request("POST", "/v1/models", "application/json", b"{\"nope\": 1}")
            .unwrap();
        assert_eq!(r.status, 400);

        // Pinned startup models refuse remote unload; the hot-loaded one
        // unloads cleanly, exactly once.
        let r = client.request("DELETE", "/v1/models/m", "", &[]).unwrap();
        assert_eq!(r.status, 403);
        let r = client.request("DELETE", "/v1/models/zoo", "", &[]).unwrap();
        assert_eq!(r.status, 200);
        let r = client.request("DELETE", "/v1/models/zoo", "", &[]).unwrap();
        assert_eq!(r.status, 404);

        // The lifecycle left OTLP spans behind: one load, one unload.
        let r = client.get("/v1/traces?format=otlp").unwrap();
        assert_eq!(r.status, 200);
        let doc = parse(&r.body);
        let spans = doc.get("resourceSpans").unwrap().as_arr().unwrap()[0]
            .get("scopeSpans")
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get("spans")
            .unwrap()
            .as_arr()
            .unwrap()
            .to_vec();
        let names: Vec<&str> =
            spans.iter().filter_map(|s| s.get("name").and_then(|n| n.as_str())).collect();
        assert!(names.contains(&"zoo.load:zoo"), "got spans: {names:?}");
        assert!(names.contains(&"zoo.unload:zoo"), "got spans: {names:?}");

        fd.shutdown();
    }

    /// `/v1/slo` serves the ledger over HTTP, strictly rejects hostile
    /// queries, and continuous profiling (1-in-N, no `--trace`) both arms
    /// `/v1/traces` and lands sampled traces in the recorder.
    #[test]
    fn slo_endpoint_and_continuous_profiling() {
        let cfg = FrontDoorConfig {
            profile_every: 2,
            profile_seed: 0,
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::start(tiny_server(), cfg).unwrap();
        let addr = fd.local_addr().to_string();
        let mut client = wire::Client::new(&addr);
        let parse = |body: &[u8]| Json::parse(std::str::from_utf8(body).unwrap()).unwrap();

        let key = VariantKey::new("m", VariantSpec::Fp32);
        let img = Tensor::from_vec(Shape::hwc(2, 2, 1), vec![1.0, -2.0, 3.0, -4.0]);
        for id in 0..6u64 {
            match client.post_infer(&key, id, &img).unwrap() {
                wire::InferOutcome::Ok(_) => {}
                other => panic!("infer {id} failed: {other:?}"),
            }
        }

        let r = client.get("/v1/slo").unwrap();
        assert_eq!(r.status, 200);
        let j = parse(&r.body);
        assert_eq!(j.get("schema").unwrap().as_str(), Some("pdq-slo-v1"));
        let vars = j.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(vars.len(), 1);
        assert_eq!(vars[0].get("variant").unwrap().as_str(), Some("m|fp32"));
        assert_eq!(vars[0].get("stages").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("autopilot").unwrap().get("enabled").unwrap().as_bool(),
            Some(false)
        );
        // Budget override changes the burn denominator; hostile spellings
        // are strict 400s, never 500s or silent defaults.
        let r = client.get("/v1/slo?budget_us=1").unwrap();
        assert_eq!(r.status, 200);
        let j = parse(&r.body);
        assert!(j.get("variants").unwrap().as_arr().unwrap()[0]
            .get("burn")
            .unwrap()
            .as_f64()
            .unwrap()
            >= 1.0);
        for bad in ["bogus=1", "budget_us=0", "q=nan", "budget_us=1&budget_us=2"] {
            let r = client.get(&format!("/v1/slo?{bad}")).unwrap();
            assert_eq!(r.status, 400, "query {bad:?} must be rejected");
        }

        // Profiling armed /v1/traces without --trace, and sampled half the
        // requests (seed 0, stride 2 → counters 0, 2, 4).
        let r = client.get("/v1/traces").unwrap();
        assert_eq!(r.status, 200);
        let (recent, _) = fd.recorder().counts();
        assert_eq!(recent, 3, "1-in-2 sampling over 6 requests");
        fd.shutdown();
    }

    #[test]
    fn connection_cap_answers_503_at_the_door() {
        use std::io::Read as _;

        let cfg = FrontDoorConfig { max_connections: 1, ..FrontDoorConfig::default() };
        let fd = FrontDoor::start(tiny_server(), cfg).unwrap();
        let addr = fd.local_addr().to_string();

        // First connection: a completed request proves it is accepted and
        // counted; keep-alive keeps the slot occupied.
        let mut holder = wire::Client::new(&addr);
        assert_eq!(holder.get("/healthz").unwrap().status, 200);

        // Second connection is over the cap: rejected before any bytes are
        // read from it, with a Retry-After hint, then closed.
        let mut over = std::net::TcpStream::connect(&addr).unwrap();
        over.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut raw = String::new();
        over.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 503 "), "got: {raw}");
        assert!(raw.to_ascii_lowercase().contains("retry-after: 1"), "got: {raw}");

        // The held connection still works: the cap rejects newcomers, it
        // does not disturb established connections.
        assert_eq!(holder.get("/healthz").unwrap().status, 200);

        drop(holder);
        let metrics = fd.shutdown();
        assert_eq!(metrics.rejected(), 1);
        assert_eq!(metrics.malformed(), 1, "connection_cap counts as malformed-input reject");
    }
}
