//! A fixed-size thread pool with drain-on-join semantics.
//!
//! The front door hands each accepted connection to this pool. On
//! [`ThreadPool::join`] the queue sender is dropped first, so workers finish
//! every job already accepted (each queued connection still gets handled and
//! each of its in-flight requests still gets a response) before the threads
//! exit — the pool-level half of the graceful-drain guarantee.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers named `{name}#{i}`.
    pub fn new(name: &str, n: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..n.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("{name}#{i}"))
                    .spawn(move || loop {
                        // Lock only to pull; run the job unlocked so
                        // siblings keep draining the queue.
                        let job = rx.lock().unwrap().recv();
                        match job {
                            // A panicking job (bad request tripping an
                            // assert somewhere) must not kill the worker:
                            // a handful of poison requests would otherwise
                            // strand the pool with no threads.
                            Ok(job) => {
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                            }
                            Err(_) => return, // sender dropped and queue drained
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Some(tx), handles }
    }

    /// Enqueue a job; `Err` after [`ThreadPool::join`] began.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) -> Result<(), ()> {
        match &self.tx {
            Some(tx) => tx.send(Box::new(job)).map_err(|_| ()),
            None => Err(()),
        }
    }

    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Stop accepting, drain every queued job, join all workers.
    pub fn join(mut self) {
        self.tx = None; // close the queue
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Implicit join for the non-explicit-shutdown path (panic unwinds,
        // early returns): same drain semantics.
        self.tx = None;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs_before_join() {
        let pool = ThreadPool::new("t", 4);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let done = Arc::clone(&done);
            pool.execute(move || {
                done.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 100, "join must drain the queue");
    }

    #[test]
    fn panicking_job_does_not_kill_workers() {
        let pool = ThreadPool::new("t", 1);
        pool.execute(|| panic!("poison job")).unwrap();
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 1, "worker must survive the panic");
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new("t", 0);
        assert_eq!(pool.size(), 1);
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        pool.join();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }
}
