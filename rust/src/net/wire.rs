//! The `/v1/infer` wire protocol and a tiny blocking client.
//!
//! Body layout (both directions):
//!
//! ```text
//! [u32 LE: preamble length] [preamble JSON] [raw f32 LE tensor data]
//! ```
//!
//! Request preamble: `{"variant": "<model>|<mode>", "id": N, "shape": [...]}`
//! with the raw data being the image tensor, row-major f32 little-endian.
//! An optional `"trace"` field (1–16 hex digits) carries a client-chosen
//! flight-recorder trace ID — the wire-level twin of the `X-PDQ-Trace`
//! header; invalid values are ignored (the server mints instead).
//! Response preamble:
//! `{"id": N, "latency_us": N, "bits": N, "shapes": [[...], ...]}` — `bits`
//! is the precision the request was actually *served* at (32 fp32, 8/4/2
//! int8 rungs; under precision brownout a degraded request reports the
//! rung it landed on, so clients can observe degradation per-response) —
//! with the raw data being every output tensor's f32 data concatenated in
//! order. When tracing is armed the response preamble echoes the request's
//! `"trace"` ID (also sent as the `X-PDQ-Trace` header); disarmed servers
//! omit the field, keeping the body bit-identical to pre-tracing builds. Raw LE f32 keeps the payload bit-exact end to end (the socket
//! integration test asserts responses match direct execution bit for bit),
//! which a decimal JSON float round-trip would not guarantee.
//!
//! Variant wire names come from [`crate::engine::VariantKey::wire`]:
//! `"micro_resnet|fp32"`, `"micro_resnet|ours-t"`,
//! `"micro_resnet|int8-ours-c"`, ...

use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::engine::VariantKey;
use crate::net::http::{read_response, HttpResponseParts, DEFAULT_MAX_BODY_BYTES};
use crate::obs::TraceId;
use crate::tensor::{Shape, Tensor};
use crate::util::json::Json;
use crate::util::prng::Pcg32;

/// Content type for the binary infer bodies.
pub const TENSOR_CONTENT_TYPE: &str = "application/x-pdq-tensor";

/// Cap on decoded tensor element counts, aligned with the body-size limit
/// (f32 = 4 bytes). Checked *before* any multiplication can overflow —
/// `Shape::numel()` is an unchecked product, and a panic in the decoder
/// would kill a connection-pool worker.
pub const MAX_TENSOR_ELEMS: usize = DEFAULT_MAX_BODY_BYTES / 4;

fn frame(preamble: &Json, raw: &[f32]) -> Vec<u8> {
    let head = preamble.to_string_compact().into_bytes();
    let mut out = Vec::with_capacity(4 + head.len() + raw.len() * 4);
    out.extend_from_slice(&(head.len() as u32).to_le_bytes());
    out.extend_from_slice(&head);
    for &x in raw {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn unframe(body: &[u8]) -> Result<(Json, Vec<f32>), String> {
    if body.len() < 4 {
        return Err("body shorter than the 4-byte preamble length".into());
    }
    let head_len = u32::from_le_bytes([body[0], body[1], body[2], body[3]]) as usize;
    let rest = &body[4..];
    if rest.len() < head_len {
        return Err(format!("preamble length {head_len} exceeds body ({} bytes)", rest.len()));
    }
    let preamble = Json::parse(
        std::str::from_utf8(&rest[..head_len]).map_err(|e| format!("non-utf8 preamble: {e}"))?,
    )?;
    let raw = &rest[head_len..];
    if raw.len() % 4 != 0 {
        return Err(format!("tensor payload of {} bytes is not a multiple of 4", raw.len()));
    }
    let data: Vec<f32> =
        raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    Ok((preamble, data))
}

fn shape_json(dims: &[usize]) -> Json {
    Json::Arr(dims.iter().map(|&d| Json::Num(d as f64)).collect())
}

fn parse_shape(j: &Json) -> Result<Shape, String> {
    let dims: Vec<usize> = j
        .as_arr()
        .ok_or("shape is not an array")?
        .iter()
        .map(|v| v.as_usize().ok_or("non-integer dim"))
        .collect::<Result<_, _>>()?;
    if dims.is_empty() {
        return Err("empty shape".into());
    }
    // Overflow-checked element count with a hard cap: attacker-controlled
    // dims must not reach `Shape::numel()`'s unchecked product.
    let mut numel: usize = 1;
    for &d in &dims {
        if d == 0 {
            return Err("zero-sized dim".into());
        }
        numel = numel
            .checked_mul(d)
            .filter(|&n| n <= MAX_TENSOR_ELEMS)
            .ok_or_else(|| format!("shape {dims:?} exceeds {MAX_TENSOR_ELEMS} elements"))?;
    }
    Ok(Shape::new(&dims))
}

/// Encode a `/v1/infer` request body.
pub fn encode_infer_request(variant: &VariantKey, id: u64, image: &Tensor<f32>) -> Vec<u8> {
    encode_infer_request_traced(variant, id, image, None)
}

/// [`encode_infer_request`] with a client-chosen trace ID in the preamble
/// (the wire-level twin of the `X-PDQ-Trace` header).
pub fn encode_infer_request_traced(
    variant: &VariantKey,
    id: u64,
    image: &Tensor<f32>,
    trace: Option<TraceId>,
) -> Vec<u8> {
    let mut p = Json::obj();
    p.set("variant", variant.wire())
        .set("id", id)
        .set("shape", shape_json(image.shape().dims()));
    if let Some(t) = trace {
        p.set("trace", t.to_string());
    }
    frame(&p, image.data())
}

/// A decoded `/v1/infer` request.
pub struct InferRequestWire {
    pub variant: VariantKey,
    pub id: u64,
    pub image: Tensor<f32>,
    /// Client-supplied trace ID from the preamble's optional `"trace"`
    /// field. Absent or unparseable values decode as `None` — a malformed
    /// trace ID must never fail an otherwise-valid request.
    pub trace: Option<TraceId>,
}

pub fn decode_infer_request(body: &[u8]) -> Result<InferRequestWire, String> {
    let (p, data) = unframe(body)?;
    let variant = VariantKey::parse_wire(
        p.get("variant").and_then(|v| v.as_str()).ok_or("missing \"variant\"")?,
    )?;
    let id = p.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let trace = p.get("trace").and_then(|v| v.as_str()).and_then(TraceId::parse);
    let shape = parse_shape(p.get("shape").ok_or("missing \"shape\"")?)?;
    if shape.numel() != data.len() {
        return Err(format!(
            "shape {} wants {} elements, payload has {}",
            shape,
            shape.numel(),
            data.len()
        ));
    }
    Ok(InferRequestWire { variant, id, image: Tensor::from_vec(shape, data), trace })
}

/// Encode a `/v1/infer` response body. `bits` is the served precision
/// (32 / 8 / 4 / 2); pass 0 to omit the field (pre-brownout encoders did).
/// `trace` echoes the request's flight-recorder ID when tracing is armed;
/// `None` omits the field, leaving the body byte-identical to pre-tracing
/// encoders.
pub fn encode_infer_response(
    id: u64,
    latency_us: u64,
    bits: u32,
    trace: Option<TraceId>,
    outputs: &[Tensor<f32>],
) -> Vec<u8> {
    let mut p = Json::obj();
    p.set("id", id).set("latency_us", latency_us);
    if bits > 0 {
        p.set("bits", bits as u64);
    }
    if let Some(t) = trace {
        p.set("trace", t.to_string());
    }
    p.set(
        "shapes",
        Json::Arr(outputs.iter().map(|t| shape_json(t.shape().dims())).collect()),
    );
    let mut raw = Vec::new();
    for t in outputs {
        raw.extend_from_slice(t.data());
    }
    frame(&p, &raw)
}

/// A decoded `/v1/infer` response.
pub struct InferResponseWire {
    pub id: u64,
    pub latency_us: u64,
    /// Served precision in bits (32 / 8 / 4 / 2); 0 when the server
    /// predates the brownout protocol and omitted the field.
    pub bits: u32,
    /// The server-echoed trace ID; `None` when tracing was disarmed (or
    /// the server predates the flight recorder).
    pub trace: Option<TraceId>,
    pub outputs: Vec<Tensor<f32>>,
}

pub fn decode_infer_response(body: &[u8]) -> Result<InferResponseWire, String> {
    let (p, data) = unframe(body)?;
    let id = p.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let latency_us = p.get("latency_us").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    let bits = p.get("bits").and_then(|v| v.as_f64()).unwrap_or(0.0) as u32;
    let trace = p.get("trace").and_then(|v| v.as_str()).and_then(TraceId::parse);
    let shapes: Vec<Shape> = p
        .get("shapes")
        .and_then(|s| s.as_arr())
        .ok_or("missing \"shapes\"")?
        .iter()
        .map(parse_shape)
        .collect::<Result<_, _>>()?;
    let total: usize = shapes.iter().map(|s| s.numel()).sum();
    if total != data.len() {
        return Err(format!("shapes want {total} elements, payload has {}", data.len()));
    }
    let mut outputs = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for s in shapes {
        let n = s.numel();
        outputs.push(Tensor::from_vec(s, data[off..off + n].to_vec()));
        off += n;
    }
    Ok(InferResponseWire { id, latency_us, bits, trace, outputs })
}

/// Outcome of one client-side infer call that got an HTTP response.
pub enum InferOutcome {
    Ok(InferResponseWire),
    /// Shed with 429; the server's retry hint in milliseconds.
    Rejected { retry_after_ms: u64 },
    /// Any other non-200 status.
    Failed { status: u16, error: String },
}

/// How hard the client fights transient failures before surfacing them.
///
/// Retries are governed by a per-request *deadline budget*, not an attempt
/// count: each retry sleeps a capped exponential backoff with seeded
/// jitter, and the loop stops as soon as the budget would be exceeded.
/// A zero budget disables retries entirely (one attempt, fail fast).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total wall-clock budget for one logical request, attempts + sleeps.
    pub budget: Duration,
    /// First backoff sleep; doubles per attempt up to `max_backoff`.
    pub base_backoff: Duration,
    /// Ceiling on a single backoff sleep (before jitter).
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            budget: Duration::from_secs(3),
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
        }
    }
}

impl RetryPolicy {
    /// One attempt, no retries, no sleeps.
    pub fn none() -> Self {
        Self { budget: Duration::ZERO, ..Self::default() }
    }
}

/// Why one attempt failed — decides whether a retry is safe.
enum SendFailure {
    /// Dialing failed: no bytes reached the server, always safe to retry.
    Connect(String),
    /// The exchange died after bytes were sent. Only safe to retry for
    /// idempotent methods — the server may have executed the request.
    Exchange(String),
}

impl SendFailure {
    fn into_msg(self) -> String {
        match self {
            SendFailure::Connect(m) | SendFailure::Exchange(m) => m,
        }
    }
}

/// A blocking keep-alive HTTP client (load generator, tests, examples).
///
/// Transient-failure handling: connect failures and dead pooled
/// connections on idempotent methods are retried under a
/// [`RetryPolicy`] deadline budget with capped exponential backoff and
/// deterministic (address-seeded) jitter. POST bodies are never blindly
/// resent after bytes hit the wire — see [`Client::request`] — but
/// [`Client::post_infer_retrying`] safely retries the *rejections* the
/// server explicitly marks retryable (429 shed / 503 drain).
pub struct Client {
    addr: String,
    stream: Option<TcpStream>,
    timeout: Duration,
    retry: RetryPolicy,
    /// Jitter source. Seeded from the address so two clients hammering
    /// the same server still decorrelate, yet a given test run is
    /// reproducible.
    rng: Pcg32,
    /// When the pooled connection last completed an exchange.
    last_used: Option<std::time::Instant>,
}

/// Redial instead of reusing a connection idle longer than this. The front
/// door silently closes keep-alive connections after ~10 s of idleness
/// (`IDLE_TICKS_MAX` × `READ_TICK`); reusing an older connection for a POST
/// would surface as a spurious transport error (POSTs are never blindly
/// retried — see [`Client::request`]). Redialing before any bytes are sent
/// is always safe.
const MAX_CONN_IDLE: Duration = Duration::from_secs(5);

impl Client {
    pub fn new(addr: &str) -> Self {
        Self::with_timeout(addr, Duration::from_secs(30))
    }

    pub fn with_timeout(addr: &str, timeout: Duration) -> Self {
        // FNV-1a over the address: a stable, spread-out jitter seed.
        let seed = addr.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3)
        });
        Self {
            addr: addr.to_string(),
            stream: None,
            timeout,
            retry: RetryPolicy::default(),
            rng: Pcg32::new(seed),
            last_used: None,
        }
    }

    /// Replace the retry policy (builder-style).
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Backoff for the given attempt number (0-based): capped exponential
    /// with multiplicative jitter in [0.5, 1.0] so a fleet of retrying
    /// clients doesn't re-dogpile the server in lockstep.
    fn backoff(&mut self, attempt: u32) -> Duration {
        let base = self.retry.base_backoff.as_secs_f64() * 2f64.powi(attempt.min(16) as i32);
        let capped = base.min(self.retry.max_backoff.as_secs_f64());
        Duration::from_secs_f64(capped * (0.5 + 0.5 * self.rng.uniform() as f64))
    }

    fn connect(&mut self) -> std::io::Result<&mut TcpStream> {
        if let Some(t) = self.last_used {
            if self.stream.is_some() && t.elapsed() > MAX_CONN_IDLE {
                self.stream = None;
            }
        }
        if self.stream.is_none() {
            let s = TcpStream::connect(&self.addr)?;
            s.set_read_timeout(Some(self.timeout))?;
            s.set_nodelay(true)?;
            self.stream = Some(s);
        }
        Ok(self.stream.as_mut().unwrap())
    }

    fn send_once(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> Result<HttpResponseParts, SendFailure> {
        let addr = self.addr.clone();
        let stream = match self.connect() {
            Ok(s) => s,
            Err(e) => return Err(SendFailure::Connect(format!("connect {addr}: {e}"))),
        };
        let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\n");
        if !body.is_empty() {
            head.push_str(&format!("Content-Type: {content_type}\r\n"));
        }
        head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
        let io = (|| {
            stream.write_all(head.as_bytes())?;
            stream.write_all(body)?;
            stream.flush()
        })();
        if let Err(e) = io {
            self.stream = None;
            return Err(SendFailure::Exchange(format!("send: {e}")));
        }
        match read_response(self.stream.as_mut().unwrap(), DEFAULT_MAX_BODY_BYTES) {
            Ok(parts) => {
                let close = parts
                    .header("connection")
                    .map(|v| v.eq_ignore_ascii_case("close"))
                    .unwrap_or(false);
                if close {
                    self.stream = None;
                }
                self.last_used = Some(std::time::Instant::now());
                Ok(parts)
            }
            Err(e) => {
                self.stream = None;
                Err(SendFailure::Exchange(format!("recv: {e}")))
            }
        }
    }

    /// One HTTP exchange, retried under the [`RetryPolicy`] deadline
    /// budget. Connect failures (no bytes sent yet) are retried for any
    /// method; exchange failures only for idempotent methods (GET/HEAD).
    /// POST bodies are never blindly resent after bytes hit the wire: a
    /// pooled connection can die after the server already received and
    /// executed the request, and a resend would double-submit the
    /// inference.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> Result<HttpResponseParts, String> {
        let deadline = Instant::now() + self.retry.budget;
        let idempotent = matches!(method, "GET" | "HEAD");
        let mut attempt = 0u32;
        loop {
            match self.send_once(method, path, content_type, body) {
                Ok(p) => return Ok(p),
                Err(f) => {
                    let retryable = matches!(f, SendFailure::Connect(_)) || idempotent;
                    let sleep = self.backoff(attempt);
                    if !retryable || Instant::now() + sleep > deadline {
                        return Err(f.into_msg());
                    }
                    std::thread::sleep(sleep);
                    attempt += 1;
                }
            }
        }
    }

    pub fn get(&mut self, path: &str) -> Result<HttpResponseParts, String> {
        self.request("GET", path, "", &[])
    }

    /// POST one image to `/v1/infer`.
    pub fn post_infer(
        &mut self,
        variant: &VariantKey,
        id: u64,
        image: &Tensor<f32>,
    ) -> Result<InferOutcome, String> {
        let body = encode_infer_request(variant, id, image);
        let parts = self.request("POST", "/v1/infer", TENSOR_CONTENT_TYPE, &body)?;
        match parts.status {
            200 => Ok(InferOutcome::Ok(decode_infer_response(&parts.body)?)),
            429 => {
                let retry_after_ms = parts
                    .header("x-pdq-retry-after-ms")
                    .and_then(|v| v.parse().ok())
                    .or_else(|| {
                        parts.header("retry-after").and_then(|v| v.parse::<u64>().ok()).map(|s| s * 1000)
                    })
                    .unwrap_or(1);
                Ok(InferOutcome::Rejected { retry_after_ms })
            }
            status => {
                let error = Json::parse(std::str::from_utf8(&parts.body).unwrap_or(""))
                    .ok()
                    .and_then(|j| j.get("error").and_then(|e| e.as_str()).map(String::from))
                    .unwrap_or_else(|| format!("http {status}"));
                Ok(InferOutcome::Failed { status, error })
            }
        }
    }

    /// [`Client::post_infer`], additionally retrying the rejections the
    /// server explicitly marks retryable — 429 overload sheds (sleeping
    /// at least the server's own retry hint) and 503 drain/connection-cap
    /// answers — within the [`RetryPolicy`] budget. Transport-level POST
    /// failures still fail fast (see [`Client::request`]); this only
    /// loops on *answered* requests, which can never double-submit. When
    /// the budget runs out, the final outcome is returned as-is so the
    /// caller still sees what the server last said.
    pub fn post_infer_retrying(
        &mut self,
        variant: &VariantKey,
        id: u64,
        image: &Tensor<f32>,
    ) -> Result<InferOutcome, String> {
        let deadline = Instant::now() + self.retry.budget;
        let mut attempt = 0u32;
        loop {
            let outcome = self.post_infer(variant, id, image)?;
            let hint = match &outcome {
                InferOutcome::Rejected { retry_after_ms } => {
                    Duration::from_millis(*retry_after_ms)
                }
                InferOutcome::Failed { status: 503, .. } => Duration::ZERO,
                _ => return Ok(outcome),
            };
            let sleep = self.backoff(attempt).max(hint);
            if Instant::now() + sleep > deadline {
                return Ok(outcome);
            }
            std::thread::sleep(sleep);
            attempt += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::VariantSpec;
    use crate::nn::QuantMode;
    use crate::quant::Granularity;

    fn key() -> VariantKey {
        VariantKey::new(
            "m",
            VariantSpec::Int8 {
                mode: QuantMode::Probabilistic,
                weight_gran: Granularity::PerTensor,
                bits: 8,
            },
        )
    }

    #[test]
    fn infer_request_roundtrip_is_bit_exact() {
        // Include values a decimal JSON float trip would mangle.
        let data = vec![0.1f32, -0.2, 1.0 / 3.0, f32::MIN_POSITIVE, 1e30, -0.0];
        let img = Tensor::from_vec(Shape::new(&[2, 3]), data.clone());
        let body = encode_infer_request(&key(), 42, &img);
        let back = decode_infer_request(&body).unwrap();
        assert_eq!(back.variant, key());
        assert_eq!(back.id, 42);
        assert_eq!(back.image.shape().dims(), &[2, 3]);
        let bits: Vec<u32> = back.image.data().iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, want, "payload must be bit-identical");
    }

    #[test]
    fn infer_response_roundtrip_multi_output() {
        let a = Tensor::from_vec(Shape::new(&[4]), vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(Shape::new(&[2, 2]), vec![-1.0, -2.0, -3.0, -4.0]);
        let body = encode_infer_response(7, 1234, 4, None, &[a.clone(), b.clone()]);
        let back = decode_infer_response(&body).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.latency_us, 1234);
        assert_eq!(back.bits, 4, "served precision rides the preamble");
        assert_eq!(back.trace, None, "disarmed tracing omits the field");
        assert_eq!(back.outputs.len(), 2);
        assert_eq!(back.outputs[0], a);
        assert_eq!(back.outputs[1], b);
        // Legacy encoders (bits 0) omit the field; decode stays tolerant.
        let legacy = encode_infer_response(7, 1234, 0, None, &[a.clone()]);
        assert_eq!(decode_infer_response(&legacy).unwrap().bits, 0);
    }

    #[test]
    fn trace_id_rides_both_preambles() {
        let id = TraceId::parse("cafef00d").unwrap();
        let img = Tensor::from_vec(Shape::new(&[4]), vec![1.0, 2.0, 3.0, 4.0]);
        // Request: traced encode decodes to the same ID; plain encode to None.
        let req = encode_infer_request_traced(&key(), 5, &img, Some(id));
        assert_eq!(decode_infer_request(&req).unwrap().trace, Some(id));
        let plain = encode_infer_request(&key(), 5, &img);
        assert_eq!(decode_infer_request(&plain).unwrap().trace, None);
        // A malformed trace field is ignored, not fatal.
        let mut p = Json::obj();
        p.set("variant", key().wire())
            .set("id", 5u64)
            .set("shape", shape_json(&[4]))
            .set("trace", "not-hex!");
        let body = frame(&p, img.data());
        let back = decode_infer_request(&body).unwrap();
        assert_eq!(back.trace, None);
        assert_eq!(back.id, 5);
        // Response echo.
        let resp = encode_infer_response(5, 10, 8, Some(id), &[img.clone()]);
        assert_eq!(decode_infer_response(&resp).unwrap().trace, Some(id));
        // Armed vs disarmed bodies differ ONLY in the preamble field.
        let disarmed = encode_infer_response(5, 10, 8, None, &[img]);
        assert_ne!(resp, disarmed);
        assert_eq!(decode_infer_response(&disarmed).unwrap().trace, None);
    }

    #[test]
    fn hostile_shapes_rejected_without_panic() {
        let hostile = |dims: &[f64]| {
            let mut p = Json::obj();
            p.set("variant", key().wire()).set("id", 1u64).set(
                "shape",
                Json::Arr(dims.iter().map(|&d| Json::Num(d)).collect()),
            );
            let head = p.to_string_compact().into_bytes();
            let mut body = Vec::new();
            body.extend_from_slice(&(head.len() as u32).to_le_bytes());
            body.extend_from_slice(&head);
            body
        };
        // 2^33 × 2^33 would overflow usize in `Shape::numel` — must be a
        // clean decode error, never a worker-killing panic.
        assert!(decode_infer_request(&hostile(&[8.589934592e9, 8.589934592e9])).is_err());
        // Valid arithmetic but over the element cap.
        assert!(decode_infer_request(&hostile(&[4097.0, 4096.0])).is_err());
        // Zero-sized and empty shapes.
        assert!(decode_infer_request(&hostile(&[0.0, 4.0])).is_err());
        assert!(decode_infer_request(&hostile(&[])).is_err());
    }

    #[test]
    fn backoff_is_deterministic_bounded_and_jittered() {
        let mut a = Client::new("127.0.0.1:1");
        let mut b = Client::new("127.0.0.1:1");
        let sa: Vec<Duration> = (0..10).map(|i| a.backoff(i)).collect();
        let sb: Vec<Duration> = (0..10).map(|i| b.backoff(i)).collect();
        assert_eq!(sa, sb, "same address seeds the same jitter schedule");

        let p = RetryPolicy::default();
        for (i, d) in sa.iter().enumerate() {
            let ideal = (p.base_backoff.as_secs_f64() * 2f64.powi(i as i32))
                .min(p.max_backoff.as_secs_f64());
            let got = d.as_secs_f64();
            assert!(
                got >= 0.5 * ideal - 1e-9 && got <= ideal + 1e-9,
                "attempt {i}: {got}s outside [{}, {ideal}]",
                0.5 * ideal
            );
        }

        let mut c = Client::new("127.0.0.1:2");
        let sc: Vec<Duration> = (0..10).map(|i| c.backoff(i)).collect();
        assert_ne!(sa, sc, "different address, different jitter phase");
    }

    #[test]
    fn zero_budget_fails_fast_on_dead_server() {
        // Bind-then-drop yields a loopback port with no listener.
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut c = Client::new(&dead).with_retry(RetryPolicy::none());
        let t0 = Instant::now();
        assert!(c.get("/healthz").is_err());
        assert!(t0.elapsed() < Duration::from_secs(2), "no retry loop on a zero budget");
    }

    #[test]
    fn connect_failures_retry_within_budget_even_for_post() {
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let policy = RetryPolicy {
            budget: Duration::from_millis(150),
            base_backoff: Duration::from_millis(30),
            max_backoff: Duration::from_millis(60),
        };
        let mut c = Client::new(&dead).with_retry(policy);
        let t0 = Instant::now();
        // Connect-phase failures never put bytes on the wire, so even a
        // POST is safe to redial until the budget runs out.
        assert!(c.request("POST", "/v1/infer", TENSOR_CONTENT_TYPE, b"x").is_err());
        assert!(
            t0.elapsed() >= Duration::from_millis(15),
            "expected at least one backoff sleep, got {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn malformed_bodies_rejected() {
        assert!(decode_infer_request(&[1, 0]).is_err(), "short body");
        assert!(decode_infer_request(&[255, 255, 0, 0]).is_err(), "preamble overruns body");
        // Valid preamble, but payload length disagrees with the shape.
        let img = Tensor::from_vec(Shape::new(&[4]), vec![0.0; 4]);
        let mut body = encode_infer_request(&key(), 1, &img);
        body.truncate(body.len() - 4);
        assert!(decode_infer_request(&body).is_err(), "shape/payload mismatch");
        body.truncate(body.len() - 2);
        assert!(decode_infer_request(&body).is_err(), "ragged f32 payload");
    }
}
