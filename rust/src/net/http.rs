//! Minimal HTTP/1.1 message framing (std-only; the offline registry has no
//! hyper).
//!
//! Scope: exactly what the PDQ front door and load generator need —
//! request-line + headers + `Content-Length` *and* chunked bodies,
//! keep-alive, and resumable reads over sockets with a read timeout. Out of
//! scope (rejected or ignored, never mis-parsed): transfer codings other
//! than `chunked` (`501`), `Expect: 100-continue` (header ignored; curl
//! falls back after its 1s expect timeout), trailer *fields* (the trailer
//! section is consumed and discarded, capped), and HTTP/2.
//!
//! Every limit here is a hostile-input defense: head size, header count,
//! chunk-size-line length, trailer bytes, and decoded body size are all
//! capped, and ambiguous framing (`Transfer-Encoding` next to
//! `Content-Length`, conflicting lengths, `+`-prefixed digits, whitespace
//! in header names) is rejected outright as request smuggling.
//!
//! The parser is *incremental*: [`RequestReader`] accumulates raw bytes and
//! yields [`ReadOutcome::Timeout`] when the underlying socket read times
//! out, preserving everything read so far. That lets a connection handler
//! poll a shutdown flag between requests without dropping a client that is
//! mid-way through sending one.

use std::io::{Read, Write};

use crate::util::json::Json;

/// Hard cap on the request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Default cap on request bodies (tensors for the tiny zoo are ~12 KB;
/// 16 MB leaves room for batched payloads without letting a client OOM us).
pub const DEFAULT_MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// Hard cap on the number of header fields in one message. Heads are
/// already byte-capped, but thousands of 1-byte headers cost an allocation
/// each — bound the count too.
pub const MAX_HEADERS: usize = 128;

/// Cap on one chunk-size line (hex digits + optional chunk extension).
/// 8 hex digits address 4 GiB; 256 bytes is generosity, not need.
const MAX_CHUNK_LINE_BYTES: usize = 256;

/// Cap on the (discarded) trailer section of a chunked body.
const MAX_TRAILER_BYTES: usize = 16 * 1024;

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    /// Path component of the target, without the query string.
    pub path: String,
    /// Raw query string (after `?`), if any.
    pub query: Option<String>,
    pub version: String,
    /// Header (name, value) pairs; names are lowercased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == want).map(|(_, v)| v.as_str())
    }

    /// `key=value` lookup in the query string (no percent-decoding; the PDQ
    /// endpoints only use bare tokens like `format=prometheus`).
    pub fn query_param(&self, key: &str) -> Option<&str> {
        let q = self.query.as_deref()?;
        q.split('&').find_map(|pair| {
            let (k, v) = pair.split_once('=')?;
            (k == key).then_some(v)
        })
    }

    /// Whether the connection should close after this exchange.
    pub fn wants_close(&self) -> bool {
        match self.header("connection") {
            // `Connection` is a comma-separated option list; "close" may
            // ride along with other tokens ("keep-alive, close").
            Some(v) => v.split(',').any(|t| t.trim().eq_ignore_ascii_case("close")),
            // HTTP/1.1 defaults to keep-alive; anything older closes.
            None => self.version != "HTTP/1.1",
        }
    }
}

/// Parse / framing errors, each mapped to the status the server should
/// answer with (`None` = the connection is unusable; just close it).
#[derive(Debug)]
pub enum HttpError {
    /// Malformed request line, header or length field → 400.
    BadRequest(String),
    /// Malformed chunked-body framing (bad size line, missing CRLF,
    /// oversized trailers) → 400, but counted separately in metrics.
    BadChunk(String),
    /// Head or body over the configured limit → 413.
    TooLarge(String),
    /// Valid HTTP we deliberately don't speak (non-chunked transfer
    /// codings) → 501.
    Unsupported(String),
    /// Peer closed mid-message.
    UnexpectedEof,
    /// Transport error.
    Io(std::io::Error),
}

impl HttpError {
    pub fn status(&self) -> Option<u16> {
        match self {
            HttpError::BadRequest(_) | HttpError::BadChunk(_) => Some(400),
            HttpError::TooLarge(_) => Some(413),
            HttpError::Unsupported(_) => Some(501),
            HttpError::UnexpectedEof | HttpError::Io(_) => None,
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::BadRequest(m) => write!(f, "bad request: {m}"),
            HttpError::BadChunk(m) => write!(f, "bad chunked body: {m}"),
            HttpError::TooLarge(m) => write!(f, "too large: {m}"),
            HttpError::Unsupported(m) => write!(f, "unsupported: {m}"),
            HttpError::UnexpectedEof => write!(f, "peer closed mid-message"),
            HttpError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

/// What one `read_request` call produced.
#[derive(Debug)]
pub enum ReadOutcome {
    Request(HttpRequest),
    /// Clean EOF on a request boundary (keep-alive peer went away).
    Eof,
    /// The socket read timed out. `idle` is true when no bytes of the next
    /// request have arrived yet — safe to close the connection or poll a
    /// shutdown flag; false means the peer is mid-request and the caller
    /// should call `read_request` again to resume.
    Timeout { idle: bool },
}

/// Where a [`RequestReader`] currently is within a request. Connection
/// handlers use this to apply *separate* head and body deadlines — a
/// slowloris client trickling header bytes gets a much shorter leash than
/// a slow-but-honest client uploading a large tensor body.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// No bytes of the next request have arrived.
    Idle,
    /// Some head bytes arrived; the `\r\n\r\n` terminator has not.
    Head,
    /// The head is complete; body bytes are still being accumulated.
    Body,
}

/// Incremental request reader over any `Read` (a `TcpStream` with a read
/// timeout in production; in-memory fakes in tests). All partial state
/// lives in `buf` (plus the chunked-body decoder cursor), so a timed-out
/// read can be resumed loss-free.
pub struct RequestReader<R: Read> {
    r: R,
    buf: Vec<u8>,
    max_body: usize,
    /// How far `buf` has already been scanned for the head terminator
    /// (re-scans restart 3 bytes back to catch a straddling `\r\n\r\n`),
    /// so accumulation is O(n), not O(n²).
    scanned: usize,
    /// Cached head end once found — body accumulation never re-scans.
    head_end: Option<usize>,
    /// In-progress chunked-body decode; `buf` is append-only while this is
    /// `Some`, so the decoder's cursor into `buf[head_len..]` stays valid
    /// across resumed reads.
    chunked: Option<ChunkDecoder>,
}

impl<R: Read> RequestReader<R> {
    pub fn new(r: R, max_body: usize) -> Self {
        Self {
            r,
            buf: Vec::with_capacity(4096),
            max_body,
            scanned: 0,
            head_end: None,
            chunked: None,
        }
    }

    /// Which part of the current request the reader is waiting on.
    pub fn stage(&self) -> Stage {
        if self.head_end.is_some() {
            Stage::Body
        } else if self.buf.is_empty() {
            Stage::Idle
        } else {
            Stage::Head
        }
    }

    /// Read (or resume reading) one request.
    pub fn read_request(&mut self) -> Result<ReadOutcome, HttpError> {
        loop {
            if self.head_end.is_none() {
                let start = self.scanned.saturating_sub(3);
                self.head_end = find_double_crlf(&self.buf[start..]).map(|i| start + i);
                self.scanned = self.buf.len();
            }
            if let Some(head_len) = self.head_end {
                if head_len > MAX_HEAD_BYTES {
                    return Err(HttpError::TooLarge("request head exceeds 16 KiB".into()));
                }
                // Head is complete; re-parsing it on each resume is cheap
                // (heads are ≤ 16 KB) and keeps the resume state small.
                let (method, path, query, version, headers) = parse_head(&self.buf[..head_len])?;
                if transfer_encoding_is_chunked(&headers)? {
                    // RFC 9112 §6.3: a message carrying both framings is
                    // the classic smuggling desync — reject, don't pick one.
                    if headers.iter().any(|(k, _)| k == "content-length") {
                        return Err(HttpError::BadRequest(
                            "transfer-encoding alongside content-length".into(),
                        ));
                    }
                    let max_body = self.max_body;
                    let dec = self.chunked.get_or_insert_with(|| ChunkDecoder::new(max_body));
                    if dec.feed(&self.buf[head_len..])? {
                        let dec = self.chunked.take().expect("decoder just fed");
                        self.buf.drain(..head_len + dec.consumed);
                        self.scanned = 0;
                        self.head_end = None;
                        return Ok(ReadOutcome::Request(HttpRequest {
                            method,
                            path,
                            query,
                            version,
                            headers,
                            body: dec.body,
                        }));
                    }
                    // Chunk framing incomplete — fall through to fill.
                } else {
                    let clen = content_length(&headers)?;
                    if clen > self.max_body {
                        return Err(HttpError::TooLarge(format!(
                            "body of {clen} bytes exceeds limit {}",
                            self.max_body
                        )));
                    }
                    if self.buf.len() >= head_len + clen {
                        let body = self.buf[head_len..head_len + clen].to_vec();
                        self.buf.drain(..head_len + clen);
                        // Any leftover bytes belong to a pipelined next
                        // request; rescanning them from 0 is cheap (they
                        // are ≤ one head).
                        self.scanned = 0;
                        self.head_end = None;
                        return Ok(ReadOutcome::Request(HttpRequest {
                            method,
                            path,
                            query,
                            version,
                            headers,
                            body,
                        }));
                    }
                }
            } else if self.buf.len() > MAX_HEAD_BYTES {
                return Err(HttpError::TooLarge("request head exceeds 16 KiB".into()));
            }
            // Need more bytes.
            match fill_once(&mut self.r, &mut self.buf)? {
                Fill::Data => {}
                Fill::Eof => {
                    return if self.buf.is_empty() {
                        Ok(ReadOutcome::Eof)
                    } else {
                        Err(HttpError::UnexpectedEof)
                    }
                }
                Fill::Timeout => return Ok(ReadOutcome::Timeout { idle: self.buf.is_empty() }),
            }
        }
    }
}

/// `Transfer-Encoding` handling: absent → `Content-Length` framing;
/// exactly `chunked` → chunked framing; anything else (gzip, coding
/// chains, repeated headers) is valid HTTP this server doesn't speak.
fn transfer_encoding_is_chunked(headers: &[(String, String)]) -> Result<bool, HttpError> {
    let mut te = headers.iter().filter(|(k, _)| k == "transfer-encoding");
    let Some((_, v)) = te.next() else { return Ok(false) };
    if te.next().is_some() {
        return Err(HttpError::BadRequest("repeated transfer-encoding header".into()));
    }
    if v.eq_ignore_ascii_case("chunked") {
        Ok(true)
    } else {
        Err(HttpError::Unsupported(format!("transfer-encoding {v:?}")))
    }
}

/// Incremental chunked-body decoder (RFC 9112 §7.1). `feed` is called with
/// the full raw slice after the head every time new bytes arrive; the
/// `consumed` cursor makes each call O(new bytes). Chunk extensions
/// (after `;`) are ignored; the trailer section is consumed, discarded and
/// byte-capped.
struct ChunkDecoder {
    state: ChunkState,
    /// Decoded body bytes.
    body: Vec<u8>,
    /// Raw bytes consumed, as an offset past the head.
    consumed: usize,
    trailer_bytes: usize,
    max_body: usize,
}

#[derive(Clone, Copy)]
enum ChunkState {
    /// Accumulating a `size[;ext]\r\n` line.
    Size,
    /// Copying chunk data.
    Data { remaining: usize },
    /// Expecting the `\r\n` that terminates a data chunk.
    DataEnd,
    /// Consuming (and discarding) trailer lines until the blank one.
    Trailers,
}

impl ChunkDecoder {
    fn new(max_body: usize) -> Self {
        Self { state: ChunkState::Size, body: Vec::new(), consumed: 0, trailer_bytes: 0, max_body }
    }

    /// Advance over `raw` (everything after the head). Returns `Ok(true)`
    /// once the terminating chunk and trailer section are fully consumed.
    fn feed(&mut self, raw: &[u8]) -> Result<bool, HttpError> {
        loop {
            let rest = &raw[self.consumed..];
            match self.state {
                ChunkState::Size => match find_crlf(rest) {
                    None => {
                        if rest.len() > MAX_CHUNK_LINE_BYTES {
                            return Err(HttpError::BadChunk("chunk size line too long".into()));
                        }
                        return Ok(false);
                    }
                    Some(i) => {
                        if i > MAX_CHUNK_LINE_BYTES {
                            return Err(HttpError::BadChunk("chunk size line too long".into()));
                        }
                        let size = parse_chunk_size(&rest[..i])?;
                        self.consumed += i + 2;
                        if size == 0 {
                            self.state = ChunkState::Trailers;
                        } else if self.body.len() + size > self.max_body {
                            return Err(HttpError::TooLarge(format!(
                                "chunked body exceeds limit {}",
                                self.max_body
                            )));
                        } else {
                            self.state = ChunkState::Data { remaining: size };
                        }
                    }
                },
                ChunkState::Data { remaining } => {
                    let take = remaining.min(rest.len());
                    self.body.extend_from_slice(&rest[..take]);
                    self.consumed += take;
                    if take == remaining {
                        self.state = ChunkState::DataEnd;
                    } else {
                        self.state = ChunkState::Data { remaining: remaining - take };
                        return Ok(false);
                    }
                }
                ChunkState::DataEnd => {
                    if rest.len() < 2 {
                        return Ok(false);
                    }
                    if &rest[..2] != b"\r\n" {
                        return Err(HttpError::BadChunk(
                            "chunk data not CRLF-terminated".into(),
                        ));
                    }
                    self.consumed += 2;
                    self.state = ChunkState::Size;
                }
                ChunkState::Trailers => match find_crlf(rest) {
                    None => {
                        if rest.len() + self.trailer_bytes > MAX_TRAILER_BYTES {
                            return Err(HttpError::BadChunk("trailer section too large".into()));
                        }
                        return Ok(false);
                    }
                    Some(i) => {
                        self.trailer_bytes += i + 2;
                        if self.trailer_bytes > MAX_TRAILER_BYTES {
                            return Err(HttpError::BadChunk("trailer section too large".into()));
                        }
                        self.consumed += i + 2;
                        if i == 0 {
                            return Ok(true);
                        }
                    }
                },
            }
        }
    }
}

/// Parse one chunk-size line (`1a` or `1a;name=value`): pure hex digits,
/// overflow-checked. Hostile sizes like `ffffffffffffffff1` must fail the
/// arithmetic, not wrap into a small allocation.
fn parse_chunk_size(line: &[u8]) -> Result<usize, HttpError> {
    let size_part = match line.iter().position(|&b| b == b';') {
        Some(i) => &line[..i],
        None => line,
    };
    if size_part.is_empty() || !size_part.iter().all(|b| b.is_ascii_hexdigit()) {
        return Err(HttpError::BadChunk(format!(
            "bad chunk size {:?}",
            String::from_utf8_lossy(line)
        )));
    }
    let mut size: usize = 0;
    for &b in size_part {
        size = size
            .checked_mul(16)
            .and_then(|s| s.checked_add((b as char).to_digit(16).unwrap() as usize))
            .ok_or_else(|| HttpError::BadChunk("chunk size overflows".into()))?;
    }
    Ok(size)
}

/// Index of the first `\r\n` in `buf`, if any.
fn find_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\r\n")
}

/// One read step, shared by the request and response readers so buffer /
/// EOF / Interrupted handling lives in exactly one place.
enum Fill {
    Data,
    Eof,
    Timeout,
}

fn fill_once<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<Fill, HttpError> {
    let mut chunk = [0u8; 4096];
    loop {
        match r.read(&mut chunk) {
            Ok(0) => return Ok(Fill::Eof),
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                return Ok(Fill::Data);
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(Fill::Timeout)
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
}

/// Index just past the `\r\n\r\n` terminating the head, if present.
fn find_double_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
}

type Head = (String, String, Option<String>, String, Vec<(String, String)>);

fn parse_head(bytes: &[u8]) -> Result<Head, HttpError> {
    let text = std::str::from_utf8(bytes)
        .map_err(|e| HttpError::BadRequest(format!("non-utf8 head: {e}")))?;
    let mut lines = text.split("\r\n");
    let request_line =
        lines.next().ok_or_else(|| HttpError::BadRequest("empty head".into()))?;
    let mut parts = request_line.split(' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| HttpError::BadRequest("missing method".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| HttpError::BadRequest("missing request target".into()))?;
    let version = parts
        .next()
        .filter(|v| v.starts_with("HTTP/"))
        .ok_or_else(|| HttpError::BadRequest("missing HTTP version".into()))?
        .to_string();
    if parts.next().is_some() {
        return Err(HttpError::BadRequest("malformed request line".into()));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    let headers = parse_header_fields(lines)?;
    Ok((method, path, query, version, headers))
}

/// Header lines → lowercased (name, value) pairs; stops at the blank line.
/// Shared by the request parser and the client-side response reader so
/// framing fixes apply to both.
fn parse_header_fields<'a>(
    lines: impl Iterator<Item = &'a str>,
) -> Result<Vec<(String, String)>, HttpError> {
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            break; // blank line before the (already-excluded) body
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::TooLarge(format!("more than {MAX_HEADERS} headers")));
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| HttpError::BadRequest(format!("malformed header {line:?}")))?;
        // RFC 9112 §5.1: whitespace between the field name and ':' MUST be
        // rejected — trimming it ("Content-Length : 5") is a smuggling
        // vector against intermediaries that parse more strictly. Field
        // names are tokens, so any embedded whitespace is malformed.
        if k.is_empty() || k.bytes().any(|b| b.is_ascii_whitespace()) {
            return Err(HttpError::BadRequest(format!("malformed header name {k:?}")));
        }
        headers.push((k.to_ascii_lowercase(), v.trim().to_string()));
    }
    Ok(headers)
}

fn content_length(headers: &[(String, String)]) -> Result<usize, HttpError> {
    let mut found: Option<usize> = None;
    for (k, v) in headers {
        if k == "content-length" {
            // RFC 9112 §6.2: the value is 1*DIGIT. `usize::from_str`
            // accepts a leading '+' ("+5"), which lenient/strict parser
            // pairs can disagree on — validate digits ourselves.
            if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
                return Err(HttpError::BadRequest(format!("bad content-length {v:?}")));
            }
            let n = v
                .parse::<usize>()
                .map_err(|_| HttpError::BadRequest(format!("bad content-length {v:?}")))?;
            // RFC 9112 §6.3: conflicting lengths desync keep-alive framing
            // (request smuggling); reject rather than let the first win.
            if matches!(found, Some(prev) if prev != n) {
                return Err(HttpError::BadRequest("conflicting content-length headers".into()));
            }
            found = Some(n);
        }
    }
    Ok(found.unwrap_or(0))
}

/// An HTTP response under construction.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn new(status: u16) -> Self {
        Self { status, headers: Vec::new(), body: Vec::new() }
    }

    pub fn json(status: u16, body: &Json) -> Self {
        Self::bytes(status, "application/json", body.to_string_compact().into_bytes())
    }

    pub fn text(status: u16, content_type: &str, body: String) -> Self {
        Self::bytes(status, content_type, body.into_bytes())
    }

    pub fn bytes(status: u16, content_type: &str, body: Vec<u8>) -> Self {
        let mut r = Self::new(status);
        r.headers.push(("Content-Type".into(), content_type.into()));
        r.body = body;
        r
    }

    /// A JSON `{"error": ...}` body.
    pub fn error(status: u16, msg: &str) -> Self {
        let mut o = Json::obj();
        o.set("error", msg);
        Self::json(status, &o)
    }

    /// Builder-style extra header.
    pub fn header(mut self, name: &str, value: &str) -> Self {
        self.headers.push((name.into(), value.into()));
        self
    }

    /// Serialize to the wire; `Content-Length` is added automatically.
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        let mut head = format!("HTTP/1.1 {} {}\r\n", self.status, reason(self.status));
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str(&format!("Content-Length: {}\r\n\r\n", self.body.len()));
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for the statuses the front door emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// A parsed HTTP response (client side: the load generator and tests).
#[derive(Clone, Debug)]
pub struct HttpResponseParts {
    pub status: u16,
    /// Lowercased header names.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponseParts {
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == want).map(|(_, v)| v.as_str())
    }
}

/// Blocking read of one full response (status line + headers +
/// `Content-Length` body). Client side only — no timeout resumption.
pub fn read_response<R: Read>(r: &mut R, max_body: usize) -> Result<HttpResponseParts, HttpError> {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    loop {
        if let Some(head_len) = find_double_crlf(&buf) {
            let text = std::str::from_utf8(&buf[..head_len])
                .map_err(|e| HttpError::BadRequest(format!("non-utf8 head: {e}")))?;
            let mut lines = text.split("\r\n");
            let status_line =
                lines.next().ok_or_else(|| HttpError::BadRequest("empty head".into()))?;
            // "HTTP/1.1 200 OK"
            let mut parts = status_line.splitn(3, ' ');
            let _version = parts
                .next()
                .filter(|v| v.starts_with("HTTP/"))
                .ok_or_else(|| HttpError::BadRequest("bad status line".into()))?;
            let status: u16 = parts
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| HttpError::BadRequest("bad status code".into()))?;
            let headers = parse_header_fields(lines)?;
            // PDQ servers always frame responses with Content-Length; a
            // chunked response means we're talking to something else.
            if headers.iter().any(|(k, _)| k == "transfer-encoding") {
                return Err(HttpError::Unsupported("chunked response bodies".into()));
            }
            let clen = content_length(&headers)?;
            if clen > max_body {
                return Err(HttpError::TooLarge(format!("response body {clen} bytes")));
            }
            while buf.len() < head_len + clen {
                fill_blocking(r, &mut buf)?;
            }
            let body = buf[head_len..head_len + clen].to_vec();
            return Ok(HttpResponseParts { status, headers, body });
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(HttpError::TooLarge("response head exceeds 16 KiB".into()));
        }
        fill_blocking(r, &mut buf)?;
    }
}

/// [`fill_once`] for the blocking client side: EOF mid-message and read
/// timeouts are both hard errors.
fn fill_blocking<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<(), HttpError> {
    match fill_once(r, buf)? {
        Fill::Data => Ok(()),
        Fill::Eof => Err(HttpError::UnexpectedEof),
        Fill::Timeout => Err(HttpError::Io(std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            "read timed out",
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn reader(bytes: &[u8]) -> RequestReader<Cursor<Vec<u8>>> {
        RequestReader::new(Cursor::new(bytes.to_vec()), DEFAULT_MAX_BODY_BYTES)
    }

    #[test]
    fn parses_get_request() {
        let mut r = reader(b"GET /healthz?format=prometheus HTTP/1.1\r\nHost: x\r\n\r\n");
        let ReadOutcome::Request(req) = r.read_request().unwrap() else { panic!("want request") };
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.query_param("format"), Some("prometheus"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.body.is_empty());
        assert!(!req.wants_close());
        // Next read: clean EOF.
        assert!(matches!(r.read_request().unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn parses_post_with_body_and_keepalive_pipeline() {
        let raw = b"POST /v1/infer HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcdGET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut r = reader(raw);
        let ReadOutcome::Request(a) = r.read_request().unwrap() else { panic!() };
        assert_eq!(a.method, "POST");
        assert_eq!(a.body, b"abcd");
        let ReadOutcome::Request(b) = r.read_request().unwrap() else { panic!() };
        assert_eq!(b.method, "GET");
        assert!(b.wants_close());
    }

    /// A Read that alternates data chunks with WouldBlock, exercising the
    /// resume path a socket read timeout takes.
    struct Stutter {
        chunks: Vec<Option<Vec<u8>>>, // None = WouldBlock
        i: usize,
    }
    impl Read for Stutter {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.i >= self.chunks.len() {
                return Ok(0);
            }
            let item = self.chunks[self.i].clone();
            self.i += 1;
            match item {
                None => Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "timeout")),
                Some(c) => {
                    let n = c.len().min(out.len());
                    out[..n].copy_from_slice(&c[..n]);
                    Ok(n)
                }
            }
        }
    }

    #[test]
    fn timeout_mid_request_resumes_without_losing_bytes() {
        let s = Stutter {
            chunks: vec![
                None, // idle timeout before anything arrived
                Some(b"POST /x HTTP/1.1\r\nContent-Le".to_vec()),
                None, // timeout mid-head
                Some(b"ngth: 3\r\n\r\nab".to_vec()),
                None, // timeout mid-body
                Some(b"c".to_vec()),
            ],
            i: 0,
        };
        let mut r = RequestReader::new(s, DEFAULT_MAX_BODY_BYTES);
        assert!(matches!(r.read_request().unwrap(), ReadOutcome::Timeout { idle: true }));
        assert!(matches!(r.read_request().unwrap(), ReadOutcome::Timeout { idle: false }));
        assert!(matches!(r.read_request().unwrap(), ReadOutcome::Timeout { idle: false }));
        let ReadOutcome::Request(req) = r.read_request().unwrap() else { panic!() };
        assert_eq!(req.body, b"abc");
    }

    #[test]
    fn rejects_bad_and_oversized_input() {
        assert!(matches!(
            reader(b"BROKEN\r\n\r\n").read_request(),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            reader(b"GET / HTTP/1.1\r\nContent-Length: zebra\r\n\r\n").read_request(),
            Err(HttpError::BadRequest(_))
        ));
        // Chunked is now decoded; other transfer codings stay 501.
        assert!(matches!(
            reader(b"GET / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n").read_request(),
            Err(HttpError::Unsupported(_))
        ));
        // Conflicting Content-Length values are a smuggling vector: reject.
        assert!(matches!(
            reader(b"POST / HTTP/1.1\r\nContent-Length: 0\r\nContent-Length: 5\r\n\r\nhello")
                .read_request(),
            Err(HttpError::BadRequest(_))
        ));
        // Identical duplicates frame normally.
        let mut dup = reader(b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nok");
        let ReadOutcome::Request(req) = dup.read_request().unwrap() else { panic!() };
        assert_eq!(req.body, b"ok");
        let huge = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES + 1));
        assert!(matches!(
            reader(huge.as_bytes()).read_request(),
            Err(HttpError::TooLarge(_))
        ));
        let mut small = RequestReader::new(
            Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\n".to_vec()),
            10,
        );
        assert!(matches!(small.read_request(), Err(HttpError::TooLarge(_))));
    }

    #[test]
    fn truncated_request_is_unexpected_eof() {
        let mut r = reader(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc");
        assert!(matches!(r.read_request(), Err(HttpError::UnexpectedEof)));
    }

    #[test]
    fn rejects_smuggling_shaped_heads() {
        // '+'-prefixed Content-Length parses under usize::from_str but is
        // not 1*DIGIT; strict/lenient parser pairs desync on it.
        assert!(matches!(
            reader(b"POST / HTTP/1.1\r\nContent-Length: +5\r\n\r\nhello").read_request(),
            Err(HttpError::BadRequest(_))
        ));
        // Whitespace before the colon must not be trimmed into validity.
        assert!(matches!(
            reader(b"POST / HTTP/1.1\r\nContent-Length : 5\r\n\r\nhello").read_request(),
            Err(HttpError::BadRequest(_))
        ));
        // Both framings present: the classic request-smuggling desync.
        assert!(matches!(
            reader(
                b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 5\r\n\r\n0\r\n\r\n"
            )
            .read_request(),
            Err(HttpError::BadRequest(_))
        ));
        // Header-count bomb: many tiny headers within the byte cap.
        let mut bomb = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 1) {
            bomb.push_str(&format!("h{i}: x\r\n"));
        }
        bomb.push_str("\r\n");
        assert!(matches!(
            reader(bomb.as_bytes()).read_request(),
            Err(HttpError::TooLarge(_))
        ));
    }

    #[test]
    fn chunked_body_decodes_and_preserves_pipelining() {
        let raw = b"POST /v1/infer HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    4\r\nabcd\r\n3;ext=ignored\r\nefg\r\n0\r\nX-Trailer: dropped\r\n\r\n\
                    GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut r = reader(raw);
        let ReadOutcome::Request(req) = r.read_request().unwrap() else { panic!() };
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"abcdefg");
        // The pipelined follow-up after the trailer section still parses.
        let ReadOutcome::Request(next) = r.read_request().unwrap() else { panic!() };
        assert_eq!(next.method, "GET");
        assert!(next.wants_close());
    }

    #[test]
    fn chunked_body_equals_content_length_twin() {
        let body = b"the quick brown fox";
        let cl = format!(
            "POST /v1/infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            std::str::from_utf8(body).unwrap()
        );
        let chunked = format!(
            "POST /v1/infer HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
             3\r\nthe\r\n{:x}\r\n{}\r\n0\r\n\r\n",
            body.len() - 3,
            std::str::from_utf8(&body[3..]).unwrap()
        );
        let ReadOutcome::Request(a) = reader(cl.as_bytes()).read_request().unwrap() else {
            panic!()
        };
        let ReadOutcome::Request(b) = reader(chunked.as_bytes()).read_request().unwrap() else {
            panic!()
        };
        assert_eq!(a.body, b.body);
        assert_eq!(a.body, body);
    }

    #[test]
    fn chunked_resumes_across_timeouts() {
        // Frames split at the nastiest boundaries: mid-size-line, mid-data,
        // mid-trailer. The decoder cursor must survive every resume.
        let s = Stutter {
            chunks: vec![
                Some(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec()),
                None,
                Some(b"4\r".to_vec()),
                None,
                Some(b"\nab".to_vec()),
                None,
                Some(b"cd\r\n0\r\n".to_vec()),
                None,
                Some(b"\r\n".to_vec()),
            ],
            i: 0,
        };
        let mut r = RequestReader::new(s, DEFAULT_MAX_BODY_BYTES);
        let req = loop {
            match r.read_request().unwrap() {
                ReadOutcome::Request(req) => break req,
                ReadOutcome::Timeout { .. } => continue,
                ReadOutcome::Eof => panic!("premature EOF"),
            }
        };
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn chunked_hostile_framing_rejected() {
        let head = "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        // Non-hex size line.
        assert!(matches!(
            reader(format!("{head}zz\r\nabcd\r\n0\r\n\r\n").as_bytes()).read_request(),
            Err(HttpError::BadChunk(_))
        ));
        // Size overflows usize: must fail checked arithmetic, not wrap.
        assert!(matches!(
            reader(format!("{head}ffffffffffffffff1\r\n").as_bytes()).read_request(),
            Err(HttpError::BadChunk(_))
        ));
        // Chunk data not CRLF-terminated.
        assert!(matches!(
            reader(format!("{head}3\r\nabcXX0\r\n\r\n").as_bytes()).read_request(),
            Err(HttpError::BadChunk(_))
        ));
        // Size line padded past the line cap.
        let long = format!("{head}1{}\r\na\r\n0\r\n\r\n", ";e".repeat(300));
        assert!(matches!(
            reader(long.as_bytes()).read_request(),
            Err(HttpError::BadChunk(_))
        ));
        // Decoded body over the configured cap → 413, before buffering it.
        let mut small = RequestReader::new(
            Cursor::new(format!("{head}ff\r\n").into_bytes()),
            10,
        );
        assert!(matches!(small.read_request(), Err(HttpError::TooLarge(_))));
    }

    #[test]
    fn connection_close_in_option_list() {
        let mut r = reader(b"GET / HTTP/1.1\r\nConnection: keep-alive, Close\r\n\r\n");
        let ReadOutcome::Request(req) = r.read_request().unwrap() else { panic!() };
        assert!(req.wants_close());
    }

    #[test]
    fn stage_tracks_head_and_body_progress() {
        let s = Stutter {
            chunks: vec![
                None,
                Some(b"POST / HTTP/1.1\r\nContent-".to_vec()),
                None,
                Some(b"Length: 3\r\n\r\n".to_vec()),
                None,
                Some(b"abc".to_vec()),
            ],
            i: 0,
        };
        let mut r = RequestReader::new(s, DEFAULT_MAX_BODY_BYTES);
        assert_eq!(r.stage(), Stage::Idle);
        r.read_request().unwrap(); // idle timeout
        assert_eq!(r.stage(), Stage::Idle);
        r.read_request().unwrap(); // timeout mid-head
        assert_eq!(r.stage(), Stage::Head);
        r.read_request().unwrap(); // timeout with head done, body pending
        assert_eq!(r.stage(), Stage::Body);
        let ReadOutcome::Request(req) = r.read_request().unwrap() else { panic!() };
        assert_eq!(req.body, b"abc");
        assert_eq!(r.stage(), Stage::Idle);
    }

    #[test]
    fn response_roundtrip() {
        let mut o = Json::obj();
        o.set("status", "ok");
        let resp = HttpResponse::json(200, &o).header("Retry-After", "1");
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: "));
        let parts = read_response(&mut Cursor::new(wire), DEFAULT_MAX_BODY_BYTES).unwrap();
        assert_eq!(parts.status, 200);
        assert_eq!(parts.header("retry-after"), Some("1"));
        assert_eq!(Json::parse(std::str::from_utf8(&parts.body).unwrap()).unwrap(), o);
    }

    #[test]
    fn reason_phrases_cover_front_door_statuses() {
        for s in [200u16, 400, 404, 405, 408, 413, 429, 500, 501, 503, 504] {
            assert_ne!(reason(s), "Unknown", "status {s}");
        }
        assert_eq!(reason(999), "Unknown");
    }
}
