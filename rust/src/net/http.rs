//! Minimal HTTP/1.1 message framing (std-only; the offline registry has no
//! hyper).
//!
//! Scope: exactly what the PDQ front door and load generator need —
//! request-line + headers + `Content-Length` bodies, keep-alive, and
//! resumable reads over sockets with a read timeout. Out of scope (rejected
//! or ignored, never mis-parsed): chunked transfer encoding (`501`),
//! `Expect: 100-continue` (header ignored; curl falls back after its 1s
//! expect timeout), trailers, and HTTP/2.
//!
//! The parser is *incremental*: [`RequestReader`] accumulates raw bytes and
//! yields [`ReadOutcome::Timeout`] when the underlying socket read times
//! out, preserving everything read so far. That lets a connection handler
//! poll a shutdown flag between requests without dropping a client that is
//! mid-way through sending one.

use std::io::{Read, Write};

use crate::util::json::Json;

/// Hard cap on the request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Default cap on request bodies (tensors for the tiny zoo are ~12 KB;
/// 16 MB leaves room for batched payloads without letting a client OOM us).
pub const DEFAULT_MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    /// Path component of the target, without the query string.
    pub path: String,
    /// Raw query string (after `?`), if any.
    pub query: Option<String>,
    pub version: String,
    /// Header (name, value) pairs; names are lowercased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == want).map(|(_, v)| v.as_str())
    }

    /// `key=value` lookup in the query string (no percent-decoding; the PDQ
    /// endpoints only use bare tokens like `format=prometheus`).
    pub fn query_param(&self, key: &str) -> Option<&str> {
        let q = self.query.as_deref()?;
        q.split('&').find_map(|pair| {
            let (k, v) = pair.split_once('=')?;
            (k == key).then_some(v)
        })
    }

    /// Whether the connection should close after this exchange.
    pub fn wants_close(&self) -> bool {
        match self.header("connection") {
            Some(v) => v.eq_ignore_ascii_case("close"),
            // HTTP/1.1 defaults to keep-alive; anything older closes.
            None => self.version != "HTTP/1.1",
        }
    }
}

/// Parse / framing errors, each mapped to the status the server should
/// answer with (`None` = the connection is unusable; just close it).
#[derive(Debug)]
pub enum HttpError {
    /// Malformed request line, header or length field → 400.
    BadRequest(String),
    /// Head or body over the configured limit → 413.
    TooLarge(String),
    /// Valid HTTP we deliberately don't speak (chunked bodies) → 501.
    Unsupported(String),
    /// Peer closed mid-message.
    UnexpectedEof,
    /// Transport error.
    Io(std::io::Error),
}

impl HttpError {
    pub fn status(&self) -> Option<u16> {
        match self {
            HttpError::BadRequest(_) => Some(400),
            HttpError::TooLarge(_) => Some(413),
            HttpError::Unsupported(_) => Some(501),
            HttpError::UnexpectedEof | HttpError::Io(_) => None,
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::BadRequest(m) => write!(f, "bad request: {m}"),
            HttpError::TooLarge(m) => write!(f, "too large: {m}"),
            HttpError::Unsupported(m) => write!(f, "unsupported: {m}"),
            HttpError::UnexpectedEof => write!(f, "peer closed mid-message"),
            HttpError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

/// What one `read_request` call produced.
#[derive(Debug)]
pub enum ReadOutcome {
    Request(HttpRequest),
    /// Clean EOF on a request boundary (keep-alive peer went away).
    Eof,
    /// The socket read timed out. `idle` is true when no bytes of the next
    /// request have arrived yet — safe to close the connection or poll a
    /// shutdown flag; false means the peer is mid-request and the caller
    /// should call `read_request` again to resume.
    Timeout { idle: bool },
}

/// Incremental request reader over any `Read` (a `TcpStream` with a read
/// timeout in production; in-memory fakes in tests). All partial state
/// lives in `buf`, so a timed-out read can be resumed loss-free.
pub struct RequestReader<R: Read> {
    r: R,
    buf: Vec<u8>,
    max_body: usize,
    /// How far `buf` has already been scanned for the head terminator
    /// (re-scans restart 3 bytes back to catch a straddling `\r\n\r\n`),
    /// so accumulation is O(n), not O(n²).
    scanned: usize,
    /// Cached head end once found — body accumulation never re-scans.
    head_end: Option<usize>,
}

impl<R: Read> RequestReader<R> {
    pub fn new(r: R, max_body: usize) -> Self {
        Self { r, buf: Vec::with_capacity(4096), max_body, scanned: 0, head_end: None }
    }

    /// Read (or resume reading) one request.
    pub fn read_request(&mut self) -> Result<ReadOutcome, HttpError> {
        loop {
            if self.head_end.is_none() {
                let start = self.scanned.saturating_sub(3);
                self.head_end = find_double_crlf(&self.buf[start..]).map(|i| start + i);
                self.scanned = self.buf.len();
            }
            if let Some(head_len) = self.head_end {
                if head_len > MAX_HEAD_BYTES {
                    return Err(HttpError::TooLarge("request head exceeds 16 KiB".into()));
                }
                // Head is complete; re-parsing it on each resume is cheap
                // (heads are ≤ 16 KB) and keeps the resume state small.
                let (method, path, query, version, headers) = parse_head(&self.buf[..head_len])?;
                if headers.iter().any(|(k, _)| k == "transfer-encoding") {
                    return Err(HttpError::Unsupported("chunked bodies not supported".into()));
                }
                let clen = content_length(&headers)?;
                if clen > self.max_body {
                    return Err(HttpError::TooLarge(format!(
                        "body of {clen} bytes exceeds limit {}",
                        self.max_body
                    )));
                }
                if self.buf.len() >= head_len + clen {
                    let body = self.buf[head_len..head_len + clen].to_vec();
                    self.buf.drain(..head_len + clen);
                    // Any leftover bytes belong to a pipelined next request;
                    // rescanning them from 0 is cheap (they are ≤ one head).
                    self.scanned = 0;
                    self.head_end = None;
                    return Ok(ReadOutcome::Request(HttpRequest {
                        method,
                        path,
                        query,
                        version,
                        headers,
                        body,
                    }));
                }
            } else if self.buf.len() > MAX_HEAD_BYTES {
                return Err(HttpError::TooLarge("request head exceeds 16 KiB".into()));
            }
            // Need more bytes.
            match fill_once(&mut self.r, &mut self.buf)? {
                Fill::Data => {}
                Fill::Eof => {
                    return if self.buf.is_empty() {
                        Ok(ReadOutcome::Eof)
                    } else {
                        Err(HttpError::UnexpectedEof)
                    }
                }
                Fill::Timeout => return Ok(ReadOutcome::Timeout { idle: self.buf.is_empty() }),
            }
        }
    }
}

/// One read step, shared by the request and response readers so buffer /
/// EOF / Interrupted handling lives in exactly one place.
enum Fill {
    Data,
    Eof,
    Timeout,
}

fn fill_once<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<Fill, HttpError> {
    let mut chunk = [0u8; 4096];
    loop {
        match r.read(&mut chunk) {
            Ok(0) => return Ok(Fill::Eof),
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                return Ok(Fill::Data);
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(Fill::Timeout)
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
}

/// Index just past the `\r\n\r\n` terminating the head, if present.
fn find_double_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
}

type Head = (String, String, Option<String>, String, Vec<(String, String)>);

fn parse_head(bytes: &[u8]) -> Result<Head, HttpError> {
    let text = std::str::from_utf8(bytes)
        .map_err(|e| HttpError::BadRequest(format!("non-utf8 head: {e}")))?;
    let mut lines = text.split("\r\n");
    let request_line =
        lines.next().ok_or_else(|| HttpError::BadRequest("empty head".into()))?;
    let mut parts = request_line.split(' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| HttpError::BadRequest("missing method".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| HttpError::BadRequest("missing request target".into()))?;
    let version = parts
        .next()
        .filter(|v| v.starts_with("HTTP/"))
        .ok_or_else(|| HttpError::BadRequest("missing HTTP version".into()))?
        .to_string();
    if parts.next().is_some() {
        return Err(HttpError::BadRequest("malformed request line".into()));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    let headers = parse_header_fields(lines)?;
    Ok((method, path, query, version, headers))
}

/// Header lines → lowercased (name, value) pairs; stops at the blank line.
/// Shared by the request parser and the client-side response reader so
/// framing fixes apply to both.
fn parse_header_fields<'a>(
    lines: impl Iterator<Item = &'a str>,
) -> Result<Vec<(String, String)>, HttpError> {
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            break; // blank line before the (already-excluded) body
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| HttpError::BadRequest(format!("malformed header {line:?}")))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    Ok(headers)
}

fn content_length(headers: &[(String, String)]) -> Result<usize, HttpError> {
    let mut found: Option<usize> = None;
    for (k, v) in headers {
        if k == "content-length" {
            let n = v
                .parse::<usize>()
                .map_err(|_| HttpError::BadRequest(format!("bad content-length {v:?}")))?;
            // RFC 9112 §6.3: conflicting lengths desync keep-alive framing
            // (request smuggling); reject rather than let the first win.
            if matches!(found, Some(prev) if prev != n) {
                return Err(HttpError::BadRequest("conflicting content-length headers".into()));
            }
            found = Some(n);
        }
    }
    Ok(found.unwrap_or(0))
}

/// An HTTP response under construction.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn new(status: u16) -> Self {
        Self { status, headers: Vec::new(), body: Vec::new() }
    }

    pub fn json(status: u16, body: &Json) -> Self {
        Self::bytes(status, "application/json", body.to_string_compact().into_bytes())
    }

    pub fn text(status: u16, content_type: &str, body: String) -> Self {
        Self::bytes(status, content_type, body.into_bytes())
    }

    pub fn bytes(status: u16, content_type: &str, body: Vec<u8>) -> Self {
        let mut r = Self::new(status);
        r.headers.push(("Content-Type".into(), content_type.into()));
        r.body = body;
        r
    }

    /// A JSON `{"error": ...}` body.
    pub fn error(status: u16, msg: &str) -> Self {
        let mut o = Json::obj();
        o.set("error", msg);
        Self::json(status, &o)
    }

    /// Builder-style extra header.
    pub fn header(mut self, name: &str, value: &str) -> Self {
        self.headers.push((name.into(), value.into()));
        self
    }

    /// Serialize to the wire; `Content-Length` is added automatically.
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        let mut head = format!("HTTP/1.1 {} {}\r\n", self.status, reason(self.status));
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str(&format!("Content-Length: {}\r\n\r\n", self.body.len()));
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for the statuses the front door emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// A parsed HTTP response (client side: the load generator and tests).
#[derive(Clone, Debug)]
pub struct HttpResponseParts {
    pub status: u16,
    /// Lowercased header names.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponseParts {
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == want).map(|(_, v)| v.as_str())
    }
}

/// Blocking read of one full response (status line + headers +
/// `Content-Length` body). Client side only — no timeout resumption.
pub fn read_response<R: Read>(r: &mut R, max_body: usize) -> Result<HttpResponseParts, HttpError> {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    loop {
        if let Some(head_len) = find_double_crlf(&buf) {
            let text = std::str::from_utf8(&buf[..head_len])
                .map_err(|e| HttpError::BadRequest(format!("non-utf8 head: {e}")))?;
            let mut lines = text.split("\r\n");
            let status_line =
                lines.next().ok_or_else(|| HttpError::BadRequest("empty head".into()))?;
            // "HTTP/1.1 200 OK"
            let mut parts = status_line.splitn(3, ' ');
            let _version = parts
                .next()
                .filter(|v| v.starts_with("HTTP/"))
                .ok_or_else(|| HttpError::BadRequest("bad status line".into()))?;
            let status: u16 = parts
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| HttpError::BadRequest("bad status code".into()))?;
            let headers = parse_header_fields(lines)?;
            let clen = content_length(&headers)?;
            if clen > max_body {
                return Err(HttpError::TooLarge(format!("response body {clen} bytes")));
            }
            while buf.len() < head_len + clen {
                fill_blocking(r, &mut buf)?;
            }
            let body = buf[head_len..head_len + clen].to_vec();
            return Ok(HttpResponseParts { status, headers, body });
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(HttpError::TooLarge("response head exceeds 16 KiB".into()));
        }
        fill_blocking(r, &mut buf)?;
    }
}

/// [`fill_once`] for the blocking client side: EOF mid-message and read
/// timeouts are both hard errors.
fn fill_blocking<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<(), HttpError> {
    match fill_once(r, buf)? {
        Fill::Data => Ok(()),
        Fill::Eof => Err(HttpError::UnexpectedEof),
        Fill::Timeout => Err(HttpError::Io(std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            "read timed out",
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn reader(bytes: &[u8]) -> RequestReader<Cursor<Vec<u8>>> {
        RequestReader::new(Cursor::new(bytes.to_vec()), DEFAULT_MAX_BODY_BYTES)
    }

    #[test]
    fn parses_get_request() {
        let mut r = reader(b"GET /healthz?format=prometheus HTTP/1.1\r\nHost: x\r\n\r\n");
        let ReadOutcome::Request(req) = r.read_request().unwrap() else { panic!("want request") };
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.query_param("format"), Some("prometheus"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.body.is_empty());
        assert!(!req.wants_close());
        // Next read: clean EOF.
        assert!(matches!(r.read_request().unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn parses_post_with_body_and_keepalive_pipeline() {
        let raw = b"POST /v1/infer HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcdGET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut r = reader(raw);
        let ReadOutcome::Request(a) = r.read_request().unwrap() else { panic!() };
        assert_eq!(a.method, "POST");
        assert_eq!(a.body, b"abcd");
        let ReadOutcome::Request(b) = r.read_request().unwrap() else { panic!() };
        assert_eq!(b.method, "GET");
        assert!(b.wants_close());
    }

    /// A Read that alternates data chunks with WouldBlock, exercising the
    /// resume path a socket read timeout takes.
    struct Stutter {
        chunks: Vec<Option<Vec<u8>>>, // None = WouldBlock
        i: usize,
    }
    impl Read for Stutter {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.i >= self.chunks.len() {
                return Ok(0);
            }
            let item = self.chunks[self.i].clone();
            self.i += 1;
            match item {
                None => Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "timeout")),
                Some(c) => {
                    let n = c.len().min(out.len());
                    out[..n].copy_from_slice(&c[..n]);
                    Ok(n)
                }
            }
        }
    }

    #[test]
    fn timeout_mid_request_resumes_without_losing_bytes() {
        let s = Stutter {
            chunks: vec![
                None, // idle timeout before anything arrived
                Some(b"POST /x HTTP/1.1\r\nContent-Le".to_vec()),
                None, // timeout mid-head
                Some(b"ngth: 3\r\n\r\nab".to_vec()),
                None, // timeout mid-body
                Some(b"c".to_vec()),
            ],
            i: 0,
        };
        let mut r = RequestReader::new(s, DEFAULT_MAX_BODY_BYTES);
        assert!(matches!(r.read_request().unwrap(), ReadOutcome::Timeout { idle: true }));
        assert!(matches!(r.read_request().unwrap(), ReadOutcome::Timeout { idle: false }));
        assert!(matches!(r.read_request().unwrap(), ReadOutcome::Timeout { idle: false }));
        let ReadOutcome::Request(req) = r.read_request().unwrap() else { panic!() };
        assert_eq!(req.body, b"abc");
    }

    #[test]
    fn rejects_bad_and_oversized_input() {
        assert!(matches!(
            reader(b"BROKEN\r\n\r\n").read_request(),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            reader(b"GET / HTTP/1.1\r\nContent-Length: zebra\r\n\r\n").read_request(),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            reader(b"GET / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").read_request(),
            Err(HttpError::Unsupported(_))
        ));
        // Conflicting Content-Length values are a smuggling vector: reject.
        assert!(matches!(
            reader(b"POST / HTTP/1.1\r\nContent-Length: 0\r\nContent-Length: 5\r\n\r\nhello")
                .read_request(),
            Err(HttpError::BadRequest(_))
        ));
        // Identical duplicates frame normally.
        let mut dup = reader(b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nok");
        let ReadOutcome::Request(req) = dup.read_request().unwrap() else { panic!() };
        assert_eq!(req.body, b"ok");
        let huge = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES + 1));
        assert!(matches!(
            reader(huge.as_bytes()).read_request(),
            Err(HttpError::TooLarge(_))
        ));
        let mut small = RequestReader::new(
            Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\n".to_vec()),
            10,
        );
        assert!(matches!(small.read_request(), Err(HttpError::TooLarge(_))));
    }

    #[test]
    fn truncated_request_is_unexpected_eof() {
        let mut r = reader(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc");
        assert!(matches!(r.read_request(), Err(HttpError::UnexpectedEof)));
    }

    #[test]
    fn response_roundtrip() {
        let mut o = Json::obj();
        o.set("status", "ok");
        let resp = HttpResponse::json(200, &o).header("Retry-After", "1");
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: "));
        let parts = read_response(&mut Cursor::new(wire), DEFAULT_MAX_BODY_BYTES).unwrap();
        assert_eq!(parts.status, 200);
        assert_eq!(parts.header("retry-after"), Some("1"));
        assert_eq!(Json::parse(std::str::from_utf8(&parts.body).unwrap()).unwrap(), o);
    }

    #[test]
    fn reason_phrases_cover_front_door_statuses() {
        for s in [200u16, 400, 404, 405, 408, 413, 429, 500, 501, 503, 504] {
            assert_ne!(reason(s), "Unknown", "status {s}");
        }
        assert_eq!(reason(999), "Unknown");
    }
}
