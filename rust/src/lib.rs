//! # PDQ — a probabilistic framework for dynamic quantization
//!
//! Rust + JAX + Pallas reproduction of *"A probabilistic framework for
//! dynamic quantization"* (Santini, Paissan, Farella — FBK, 2025).
//!
//! The paper's contribution is a quantization-parameter *estimator*: instead
//! of storing the full pre-activation tensor to measure its dynamic range
//! (dynamic quantization) or freezing parameters at calibration time (static
//! quantization), PDQ predicts the output mean/variance from the *input* and
//! the layer's weight statistics, under the surrogate assumption that weights
//! are i.i.d. Gaussian. The predicted interval `I(α,β) = [µ−ασ, µ+βσ]` is
//! used as the dynamic range, so the output can be requantized on the fly
//! with O(1) memory overhead.
//!
//! ## Crate layout (Layer 3 — the runtime; python layers are build-time only)
//!
//! - [`util`] — substrates the offline registry could not provide: PRNG,
//!   JSON, CLI parsing, a mini property-testing framework, table rendering.
//! - [`tensor`] — a small NHWC tensor library.
//! - [`quant`] — uniform affine quantization (paper §2.1/Eq. 1–4), CMSIS
//!   style fixed-point requantization, Newton–Raphson integer sqrt.
//! - [`estimator`] — the paper's core contribution (§4, Eq. 8–13): moment
//!   propagation for linear/conv layers, γ-strided sampling, interval
//!   coverage calibration.
//! - [`engine`] — **the crate's front-door API**: one `Engine`/`Session`
//!   abstraction over fp32, fake-quant, and int8 execution, with an
//!   `EngineBuilder` construction path, stable `VariantSpec` wire naming,
//!   a `SessionPool` for per-worker reuse, and typed `EngineError`s.
//!   Prefer it over driving the executors below directly.
//! - [`nn`] — graph IR + float executor + fake-quant executor with
//!   Static / Dynamic / Probabilistic requantization modes (§3, Fig. 1).
//! - [`cmsis`] — true-int8 kernels mirroring `arm_convolve_s8` /
//!   `arm_fully_connected_s8` plus the paper's estimate-then-convolve
//!   wrappers (§5.1).
//! - [`mcu`] — Cortex-M4 cycle cost model used for the on-device latency
//!   study (Fig. 3).
//! - [`data`] — procedural synthetic datasets + the corruption suite
//!   (Fig. 2) standing in for ImageNet/COCO/DOTA (see DESIGN.md).
//! - [`models`] — the model zoo: `.pqw` weight loading and graph builders.
//! - [`eval`] — top-1, mAP50-95, OKS, OBB/segmentation IoU metrics.
//! - [`runtime`] — PJRT client wrapper loading the AOT HLO artifacts.
//! - [`adapt`] — online adaptation: sampled per-node drift observation on
//!   live traffic, background shadow recalibration, and atomic epoch swaps
//!   of serving grids (zero-downtime).
//! - [`artifact`] — compiled model artifacts (`pdq-artifact-v1`): packed,
//!   checksummed, mmap-loadable serving programs carrying the full 13-cell
//!   menu from one weight copy, so calibration and serving can run on
//!   different machines (`pdq pack` / `pdq inspect` / `pdq repack`).
//! - [`coordinator`] — threaded serving stack: router → dynamic batcher →
//!   worker pool, calibration orchestration, metrics.
//! - [`net`] — the network front door: std-only HTTP/1.1 ingress over the
//!   coordinator (admission control, graceful drain) plus the socket-level
//!   load-generation harness.
//! - [`obs`] — the flight recorder: end-to-end request tracing with
//!   per-stage and per-node kernel spans, a ring buffer of recent +
//!   anomalous traces behind `GET /v1/traces`, structured rate-limited
//!   event logging, and `pdq perf-report` commit-to-commit bench deltas.
//! - [`harness`] — experiment drivers regenerating every paper table/figure.
//! - [`testing`] — deterministic fuzzing harness (seeded mutators,
//!   grammar-aware generators, differential int8 targets) shared by the
//!   in-tree fuzz smoke tests and the out-of-tree `fuzz/` cargo-fuzz tree.

pub mod adapt;
pub mod artifact;
pub mod cmsis;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod estimator;
pub mod eval;
pub mod harness;
pub mod mcu;
pub mod models;
pub mod net;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod util;
