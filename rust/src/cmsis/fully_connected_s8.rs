//! `arm_fully_connected_s8` port: int8 matrix–vector product.

use super::requant::Requant;
use crate::tensor::Tensor;
#[cfg(test)]
use crate::tensor::Shape;

/// int8 fully connected: `weights [h, d]` row-major, `x` length d.
pub fn fully_connected_s8(
    x: &[i8],
    weights: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
    requant: &Requant,
) -> Vec<i8> {
    fully_connected_s8_acc(x, weights, bias, input_offset)
        .iter()
        .enumerate()
        .map(|(j, &a)| requant.apply(a, j))
        .collect()
}

/// Wide accumulator variant.
pub fn fully_connected_s8_acc(
    x: &[i8],
    weights: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
) -> Vec<i32> {
    let (h, d) = (weights.shape().dim(0), weights.shape().dim(1));
    assert_eq!(x.len(), d, "fc input length");
    assert_eq!(bias.len(), h, "fc bias length");
    let wd = weights.data();
    let mut out = Vec::with_capacity(h);
    for j in 0..h {
        let row = &wd[j * d..(j + 1) * d];
        let mut acc = bias[j];
        for i in 0..d {
            acc += (x[i] as i32 + input_offset) * row[i] as i32;
        }
        out.push(acc);
    }
    out
}

/// Helper shared with the estimator path: quantize a float weight matrix to
/// symmetric int8 (per-tensor) returning `(q, scale)`.
pub fn quantize_weights_symmetric(w: &[f32]) -> (Vec<i8>, f32) {
    let absmax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-9);
    let s = absmax / 127.0;
    (w.iter().map(|&v| (v / s).round().clamp(-127.0, 127.0) as i8).collect(), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::Checker;

    #[test]
    fn known_product() {
        let w = Tensor::from_vec(Shape::new(&[2, 3]), vec![1i8, 2, 3, -1, 0, 1]);
        let acc = fully_connected_s8_acc(&[10, 20, 30], &w, &[5, -5], 0);
        assert_eq!(acc, vec![10 + 40 + 90 + 5, -10 + 30 - 5]);
    }

    #[test]
    fn input_offset() {
        let w = Tensor::from_vec(Shape::new(&[1, 2]), vec![1i8, 1]);
        let acc = fully_connected_s8_acc(&[0, 0], &w, &[0], 3);
        assert_eq!(acc, vec![6]);
    }

    #[test]
    fn exact_integer_match_vs_float() {
        Checker::new(0xFC, 30).check("fc int == float int", |rng| {
            let d = rng.int_range(1, 64) as usize;
            let h = rng.int_range(1, 16) as usize;
            let x: Vec<i8> = (0..d).map(|_| rng.int_range(-128, 127) as i8).collect();
            let w: Vec<i8> = (0..h * d).map(|_| rng.int_range(-127, 127) as i8).collect();
            let bias: Vec<i32> = (0..h).map(|_| rng.int_range(-1000, 1000) as i32).collect();
            let off = rng.int_range(-10, 10) as i32;
            let wt = Tensor::from_vec(Shape::new(&[h, d]), w.clone());
            let acc = fully_connected_s8_acc(&x, &wt, &bias, off);
            for j in 0..h {
                let mut want = bias[j] as i64;
                for i in 0..d {
                    want += (x[i] as i64 + off as i64) * w[j * d + i] as i64;
                }
                if acc[j] as i64 != want {
                    return Err(format!("row {j}: {} vs {want}", acc[j]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn symmetric_weight_quantization_bounds() {
        let w = [0.5f32, -1.0, 0.25];
        let (q, s) = quantize_weights_symmetric(&w);
        assert_eq!(q[1], -127);
        for (i, &v) in w.iter().enumerate() {
            assert!((q[i] as f32 * s - v).abs() <= s * 0.5 + 1e-6);
        }
    }
}
