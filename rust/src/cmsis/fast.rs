//! Fast int8 kernels: im2col + register-blocked i8×i8→i32 GEMM with the
//! requantization fused into the accumulator sweep.
//!
//! These are the serving-speed counterparts of the naive scalar ports in
//! [`super::convolve_s8`] / [`super::dwconv_s8`] /
//! [`super::fully_connected_s8`], which stay untouched as the parity
//! oracle. The contract is **bit-exactness**: integer accumulation is
//! associative, so reordering the taps into a patch-matrix GEMM produces
//! the same i32 accumulator the naive loop produces, and the same
//! [`Requant`] epilogue then yields the same int8 output
//! (`rust/tests/int8_parity.rs` checks exact equality).
//!
//! The epilogue is generic over the output element: static/PDQ requantize
//! each accumulator to `i8` as it leaves the register block, so the i32
//! tensor never exists (the paper's O(1)-memory property, enforced by
//! construction); the dynamic wrapper instantiates the same kernels with an
//! identity `i32` epilogue and pays the §3 `b′·h` buffer deliberately.
//!
//! **Nested bit-width rungs.** Every kernel also comes in a `_shifted`
//! variant taking a `weight_shift`: the weight is truncated to `8 - shift`
//! bits at load time via an arithmetic right shift (DQT-style nested
//! integer arithmetic — the 4/2-bit programs live inside the stored 8-bit
//! weights, no second weight copy). Sign extension commutes with the
//! arithmetic shift, so `(w as i32) >> s == ((w >> s) as i32)` and the
//! fast inline-shift path is bit-exact against a naive kernel fed a
//! materialized `w >> s` tensor. The plain entry points delegate with
//! shift 0, which the optimizer folds away — the 8-bit path is unchanged.

use super::requant::Requant;
use crate::tensor::{ConvGeom, Tensor};

/// im2col for int8 inputs: every output pixel's receptive field becomes a
/// contiguous `[kh·kw·cin]` row of `cols`, stored as `q + input_offset` in
/// i32. Padded taps keep the value 0, so — exactly like the naive kernel's
/// `continue` — padding contributes nothing to the accumulator. Returns
/// `(rows, k)`.
pub fn im2col_s8(
    input: &Tensor<i8>,
    geom: &ConvGeom,
    input_offset: i32,
    cols: &mut Vec<i32>,
) -> (usize, usize) {
    let (h, w, cin) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    let (oh, ow) = geom.out_dims(h, w);
    let k = geom.kh * geom.kw * cin;
    let m = oh * ow;
    cols.clear();
    cols.resize(m * k, 0);
    let xd = input.data();
    for oy in 0..oh {
        let y_origin = (oy * geom.stride) as isize - geom.pad as isize;
        for ox in 0..ow {
            let x_origin = (ox * geom.stride) as isize - geom.pad as isize;
            let row = (oy * ow + ox) * k;
            for dy in 0..geom.kh {
                let yy = y_origin + dy as isize;
                if yy < 0 || yy >= h as isize {
                    continue; // padded row: keep the zeros
                }
                let dx0 = (-x_origin).max(0) as usize;
                let dx1 = ((w as isize - x_origin).min(geom.kw as isize)).max(0) as usize;
                if dx1 <= dx0 {
                    continue;
                }
                let src = (yy as usize * w + (x_origin + dx0 as isize) as usize) * cin;
                let dst = row + (dy * geom.kw + dx0) * cin;
                let len = (dx1 - dx0) * cin;
                for (d, &s) in cols[dst..dst + len].iter_mut().zip(xd[src..src + len].iter()) {
                    *d = s as i32 + input_offset;
                }
            }
        }
    }
    (m, k)
}

/// `out[i·n + j] = epi(bias[j] + Σ_p a[i·k + p] · b[j·k + p], j)` — C = A·Bᵀ
/// with i32 accumulation and a fused per-element epilogue. `a` is the
/// offset-shifted patch matrix, `b` row-major `[n, k]` is the flattened
/// OHWI conv weight (or `[h, d]` linear weight) as-is. 4×8 register-blocked
/// microkernel; the epilogue decides the output element type (`i8` for a
/// fused requantize, `i32` for the dynamic wrapper's wide buffer).
#[allow(clippy::too_many_arguments)]
pub fn gemm_s8_nt<T: Copy + Default, E: Fn(i32, usize) -> T>(
    m: usize,
    n: usize,
    k: usize,
    a: &[i32],
    b: &[i8],
    bias: &[i32],
    out: &mut [T],
    epi: E,
) {
    gemm_s8_nt_shifted(m, n, k, a, b, bias, 0, out, epi)
}

/// [`gemm_s8_nt`] with the weight truncated to a nested rung at load time:
/// every `b` element is arithmetically shifted right by `weight_shift`
/// before the multiply, so the accumulator lives on the
/// `s_in · s_w · 2^weight_shift` grid.
#[allow(clippy::too_many_arguments)]
pub fn gemm_s8_nt_shifted<T: Copy + Default, E: Fn(i32, usize) -> T>(
    m: usize,
    n: usize,
    k: usize,
    a: &[i32],
    b: &[i8],
    bias: &[i32],
    weight_shift: u32,
    out: &mut [T],
    epi: E,
) {
    assert_eq!(a.len(), m * k, "gemm_s8: a is [m, k]");
    assert_eq!(b.len(), n * k, "gemm_s8: b is [n, k]");
    assert_eq!(bias.len(), n, "gemm_s8: bias is [n]");
    assert_eq!(out.len(), m * n, "gemm_s8: out is [m, n]");
    assert!(weight_shift < 8, "gemm_s8: shift must leave at least one weight bit");
    const MR: usize = 4;
    const NR: usize = 8;
    let mut i = 0;
    while i < m {
        let ib = MR.min(m - i);
        let mut j = 0;
        while j < n {
            let jb = NR.min(n - j);
            let mut acc = [[0i32; NR]; MR];
            for p in 0..k {
                let mut bv = [0i32; NR];
                for c in 0..jb {
                    bv[c] = (b[(j + c) * k + p] as i32) >> weight_shift;
                }
                for r in 0..ib {
                    let av = a[(i + r) * k + p];
                    for (accv, &bvv) in acc[r].iter_mut().zip(bv.iter()) {
                        *accv += av * bvv;
                    }
                }
            }
            for r in 0..ib {
                for c in 0..jb {
                    out[(i + r) * n + j + c] = epi(bias[j + c] + acc[r][c], j + c);
                }
            }
            j += NR;
        }
        i += MR;
    }
}

/// Fast int8 convolution: [`im2col_s8`] + [`gemm_s8_nt`]. `input` HWC,
/// `kernel` OHWI, `out` length `oh·ow·cout`. `epi` maps each finished i32
/// accumulator (bias included) and its output channel to the stored element.
#[allow(clippy::too_many_arguments)]
pub fn convolve_s8_fast<T: Copy + Default, E: Fn(i32, usize) -> T>(
    input: &Tensor<i8>,
    kernel: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
    geom: &ConvGeom,
    cols: &mut Vec<i32>,
    out: &mut [T],
    epi: E,
) {
    convolve_s8_fast_shifted(input, kernel, bias, input_offset, 0, geom, cols, out, epi)
}

/// [`convolve_s8_fast`] on a nested rung: the stored 8-bit weights are
/// truncated by `weight_shift` inside the GEMM load — no shifted weight
/// tensor is ever materialized.
#[allow(clippy::too_many_arguments)]
pub fn convolve_s8_fast_shifted<T: Copy + Default, E: Fn(i32, usize) -> T>(
    input: &Tensor<i8>,
    kernel: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
    weight_shift: u32,
    geom: &ConvGeom,
    cols: &mut Vec<i32>,
    out: &mut [T],
    epi: E,
) {
    let (cout, kh, kw, kcin) =
        (kernel.shape().dim(0), kernel.shape().dim(1), kernel.shape().dim(2), kernel.shape().dim(3));
    assert_eq!(input.shape().dim(2), kcin, "conv channel mismatch");
    assert_eq!((kh, kw), (geom.kh, geom.kw));
    assert_eq!(bias.len(), cout);
    let (m, k) = im2col_s8(input, geom, input_offset, cols);
    assert_eq!(out.len(), m * cout, "conv output length");
    gemm_s8_nt_shifted(m, cout, k, cols, kernel.data(), bias, weight_shift, out, epi);
}

/// Fast int8 depthwise convolution. The `[C, kh, kw]` weights are
/// transposed once per call into `wt_scratch` as `[kh·kw, C]` so the inner
/// loop is a contiguous multiply-add across channels; `acc_row` holds the
/// C running accumulators of the current output pixel (O(C) scratch — the
/// same order as the requant parameter vectors, never O(h)).
#[allow(clippy::too_many_arguments)]
pub fn dwconv_s8_fast<T: Copy + Default, E: Fn(i32, usize) -> T>(
    input: &Tensor<i8>,
    kernel: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
    geom: &ConvGeom,
    wt_scratch: &mut Vec<i8>,
    acc_row: &mut Vec<i32>,
    out: &mut [T],
    epi: E,
) {
    dwconv_s8_fast_shifted(input, kernel, bias, input_offset, 0, geom, wt_scratch, acc_row, out, epi)
}

/// [`dwconv_s8_fast`] on a nested rung: the truncation rides the per-call
/// `[kh·kw, C]` transpose (an i8 arithmetic shift is closed over i8), so
/// the inner pixel loop is untouched.
#[allow(clippy::too_many_arguments)]
pub fn dwconv_s8_fast_shifted<T: Copy + Default, E: Fn(i32, usize) -> T>(
    input: &Tensor<i8>,
    kernel: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
    weight_shift: u32,
    geom: &ConvGeom,
    wt_scratch: &mut Vec<i8>,
    acc_row: &mut Vec<i32>,
    out: &mut [T],
    epi: E,
) {
    let (h, w, c) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    let (kc, kh, kw) = (kernel.shape().dim(0), kernel.shape().dim(1), kernel.shape().dim(2));
    assert_eq!(c, kc, "dwconv channel mismatch");
    assert_eq!((kh, kw), (geom.kh, geom.kw));
    assert_eq!(bias.len(), c);
    assert!(weight_shift < 8, "dwconv: shift must leave at least one weight bit");
    let (oh, ow) = geom.out_dims(h, w);
    assert_eq!(out.len(), oh * ow * c, "dwconv output length");
    let taps = kh * kw;
    wt_scratch.clear();
    wt_scratch.resize(taps * c, 0);
    let kd = kernel.data();
    for ch in 0..c {
        for t in 0..taps {
            wt_scratch[t * c + ch] = kd[ch * taps + t] >> weight_shift;
        }
    }
    acc_row.clear();
    acc_row.resize(c, 0);
    let xd = input.data();
    for oy in 0..oh {
        let y_origin = (oy * geom.stride) as isize - geom.pad as isize;
        let (y0, y1) = geom.in_range_y(oy, h);
        for ox in 0..ow {
            let x_origin = (ox * geom.stride) as isize - geom.pad as isize;
            let (x0, x1) = geom.in_range_x(ox, w);
            acc_row.copy_from_slice(bias);
            for yy in y0..y1 {
                let dy = (yy as isize - y_origin) as usize;
                for xx in x0..x1 {
                    let dx = (xx as isize - x_origin) as usize;
                    let xpix = &xd[(yy * w + xx) * c..][..c];
                    let wpix = &wt_scratch[(dy * kw + dx) * c..][..c];
                    for ((acc, &xv), &wv) in
                        acc_row.iter_mut().zip(xpix.iter()).zip(wpix.iter())
                    {
                        *acc += (xv as i32 + input_offset) * wv as i32;
                    }
                }
            }
            let opix = &mut out[(oy * ow + ox) * c..][..c];
            for (ch, (o, &acc)) in opix.iter_mut().zip(acc_row.iter()).enumerate() {
                *o = epi(acc, ch);
            }
        }
    }
}

/// Fast int8 fully connected: the per-element `(x + offset) · w` of the
/// naive port distributes into `Σ x·w + offset · Σ w`, so the offset is
/// applied once per row via the precomputed weight row sums (exact — pure
/// integer distributivity). `w_row_sums[j] = Σ_i weights[j, i]`.
pub fn fully_connected_s8_fast<T: Copy + Default, E: Fn(i32, usize) -> T>(
    x: &[i8],
    weights: &Tensor<i8>,
    bias: &[i32],
    w_row_sums: &[i32],
    input_offset: i32,
    out: &mut [T],
    epi: E,
) {
    fully_connected_s8_fast_shifted(x, weights, bias, w_row_sums, input_offset, 0, out, epi)
}

/// [`fully_connected_s8_fast`] on a nested rung. `w_row_sums` must be the
/// row sums of the **truncated** weights (`Σ_i (w[j,i] >> s)` — see
/// [`weight_row_sums_shifted`]): truncation does not distribute over the
/// sum, so each rung carries its own deploy-time row-sum vector.
#[allow(clippy::too_many_arguments)]
pub fn fully_connected_s8_fast_shifted<T: Copy + Default, E: Fn(i32, usize) -> T>(
    x: &[i8],
    weights: &Tensor<i8>,
    bias: &[i32],
    w_row_sums: &[i32],
    input_offset: i32,
    weight_shift: u32,
    out: &mut [T],
    epi: E,
) {
    let (h, d) = (weights.shape().dim(0), weights.shape().dim(1));
    assert_eq!(x.len(), d, "fc input length");
    assert_eq!(bias.len(), h, "fc bias length");
    assert_eq!(w_row_sums.len(), h, "fc row-sum length");
    assert_eq!(out.len(), h, "fc output length");
    assert!(weight_shift < 8, "fc: shift must leave at least one weight bit");
    let wd = weights.data();
    for j in 0..h {
        let row = &wd[j * d..(j + 1) * d];
        let mut acc = bias[j] + input_offset * w_row_sums[j];
        for (&xv, &wv) in x.iter().zip(row.iter()) {
            acc += xv as i32 * ((wv as i32) >> weight_shift);
        }
        out[j] = epi(acc, j);
    }
}

/// Row sums of an `[h, d]` int8 weight matrix (deploy-time constant for
/// [`fully_connected_s8_fast`]).
pub fn weight_row_sums(weights: &Tensor<i8>) -> Vec<i32> {
    weight_row_sums_shifted(weights, 0)
}

/// Row sums of the rung-truncated weights, `Σ_i (w[j,i] >> s)` — the
/// deploy-time constant for [`fully_connected_s8_fast_shifted`].
pub fn weight_row_sums_shifted(weights: &Tensor<i8>, weight_shift: u32) -> Vec<i32> {
    let (h, d) = (weights.shape().dim(0), weights.shape().dim(1));
    let wd = weights.data();
    (0..h)
        .map(|j| wd[j * d..(j + 1) * d].iter().map(|&v| (v as i32) >> weight_shift).sum())
        .collect()
}

/// Convenience epilogue: requantize through `r` (the common i8 instantiation).
#[inline]
pub fn requant_epi(r: &Requant) -> impl Fn(i32, usize) -> i8 + '_ {
    move |acc, ch| r.apply(acc, ch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cmsis::convolve_s8::convolve_s8_acc;
    use crate::cmsis::dwconv_s8::dwconv_s8_acc;
    use crate::cmsis::fully_connected_s8::fully_connected_s8_acc;
    use crate::tensor::Shape;
    use crate::util::check::Checker;

    fn rand_i8(rng: &mut crate::util::Pcg32, n: usize, lo: i64, hi: i64) -> Vec<i8> {
        (0..n).map(|_| rng.int_range(lo, hi) as i8).collect()
    }

    #[test]
    fn conv_fast_acc_bit_exact_vs_naive() {
        Checker::new(0x51D8, 40).check("convolve_s8_fast == convolve_s8_acc", |rng| {
            let h = rng.int_range(3, 10) as usize;
            let w = rng.int_range(3, 10) as usize;
            let cin = rng.int_range(1, 6) as usize;
            let cout = rng.int_range(1, 7) as usize;
            let k = *rng.choice(&[1usize, 3]);
            let stride = *rng.choice(&[1usize, 2]);
            let pad = *rng.choice(&[0usize, k / 2]);
            let geom = ConvGeom::new(k, k, stride, pad);
            let x = Tensor::from_vec(Shape::hwc(h, w, cin), rand_i8(rng, h * w * cin, -128, 127));
            let kt =
                Tensor::from_vec(Shape::ohwi(cout, k, k, cin), rand_i8(rng, cout * k * k * cin, -127, 127));
            let bias: Vec<i32> = (0..cout).map(|_| rng.int_range(-2000, 2000) as i32).collect();
            let off = rng.int_range(-128, 128) as i32;
            let want = convolve_s8_acc(&x, &kt, &bias, off, &geom);
            let mut cols = Vec::new();
            let mut got = vec![0i32; want.numel()];
            convolve_s8_fast(&x, &kt, &bias, off, &geom, &mut cols, &mut got, |a, _| a);
            if got != *want.data() {
                return Err(format!("acc mismatch (h{h} w{w} cin{cin} cout{cout} k{k} s{stride} p{pad})"));
            }
            Ok(())
        });
    }

    #[test]
    fn dwconv_fast_acc_bit_exact_vs_naive() {
        Checker::new(0x51D9, 40).check("dwconv_s8_fast == dwconv_s8_acc", |rng| {
            let h = rng.int_range(3, 10) as usize;
            let w = rng.int_range(3, 10) as usize;
            let c = rng.int_range(1, 8) as usize;
            let k = *rng.choice(&[1usize, 3]);
            let stride = *rng.choice(&[1usize, 2]);
            let pad = *rng.choice(&[0usize, k / 2]);
            let geom = ConvGeom::new(k, k, stride, pad);
            let x = Tensor::from_vec(Shape::hwc(h, w, c), rand_i8(rng, h * w * c, -128, 127));
            let kt = Tensor::from_vec(Shape::new(&[c, k, k]), rand_i8(rng, c * k * k, -127, 127));
            let bias: Vec<i32> = (0..c).map(|_| rng.int_range(-2000, 2000) as i32).collect();
            let off = rng.int_range(-128, 128) as i32;
            let want = dwconv_s8_acc(&x, &kt, &bias, off, &geom);
            let mut wt = Vec::new();
            let mut acc_row = Vec::new();
            let mut got = vec![0i32; want.numel()];
            dwconv_s8_fast(&x, &kt, &bias, off, &geom, &mut wt, &mut acc_row, &mut got, |a, _| a);
            if got != *want.data() {
                return Err(format!("dw acc mismatch (h{h} w{w} c{c} k{k} s{stride} p{pad})"));
            }
            Ok(())
        });
    }

    #[test]
    fn fc_fast_bit_exact_vs_naive() {
        Checker::new(0x51DA, 60).check("fully_connected_s8_fast == naive", |rng| {
            let d = rng.int_range(1, 128) as usize;
            let hh = rng.int_range(1, 24) as usize;
            let x = rand_i8(rng, d, -128, 127);
            let wt = Tensor::from_vec(Shape::new(&[hh, d]), rand_i8(rng, hh * d, -127, 127));
            let bias: Vec<i32> = (0..hh).map(|_| rng.int_range(-5000, 5000) as i32).collect();
            let off = rng.int_range(-128, 128) as i32;
            let want = fully_connected_s8_acc(&x, &wt, &bias, off);
            let sums = weight_row_sums(&wt);
            let mut got = vec![0i32; hh];
            fully_connected_s8_fast(&x, &wt, &bias, &sums, off, &mut got, |a, _| a);
            if got != want {
                return Err(format!("fc mismatch (h{hh} d{d} off{off})"));
            }
            Ok(())
        });
    }

    #[test]
    fn fused_requant_epilogue_matches_two_pass() {
        // epi-fused i8 output == naive acc + separate requant sweep.
        let mut rng = crate::util::Pcg32::new(0x51DB);
        let geom = ConvGeom::same(3, 1);
        let x = Tensor::from_vec(Shape::hwc(6, 5, 3), rand_i8(&mut rng, 90, -128, 127));
        let kt = Tensor::from_vec(Shape::ohwi(4, 3, 3, 3), rand_i8(&mut rng, 108, -127, 127));
        let bias = vec![100i32, -50, 0, 7];
        let r = Requant::per_channel(&[0.02, 0.013, 0.4, 0.0021], -3);
        let want = crate::cmsis::convolve_s8(&x, &kt, &bias, 5, &r, &geom);
        let mut cols = Vec::new();
        let mut got = vec![0i8; want.numel()];
        convolve_s8_fast(&x, &kt, &bias, 5, &geom, &mut cols, &mut got, requant_epi(&r));
        assert_eq!(&got, want.data());
    }

    #[test]
    fn shifted_kernels_bit_exact_vs_naive_on_truncated_weights() {
        // Inline `(w as i32) >> s` in the fast path must equal the naive
        // kernels fed a materialized `w >> s` i8 tensor, for every rung.
        Checker::new(0x51DC, 30).check("shifted fast == naive(w >> s)", |rng| {
            let shift = *rng.choice(&[4u32, 6]);
            let h = rng.int_range(3, 8) as usize;
            let w = rng.int_range(3, 8) as usize;
            let cin = rng.int_range(1, 5) as usize;
            let cout = rng.int_range(1, 6) as usize;
            let geom = ConvGeom::same(3, 1);
            let x = Tensor::from_vec(Shape::hwc(h, w, cin), rand_i8(rng, h * w * cin, -128, 127));
            let kt = Tensor::from_vec(
                Shape::ohwi(cout, 3, 3, cin),
                rand_i8(rng, cout * 9 * cin, -127, 127),
            );
            let bias: Vec<i32> = (0..cout).map(|_| rng.int_range(-2000, 2000) as i32).collect();
            let off = rng.int_range(-128, 128) as i32;
            let kt_trunc = Tensor::from_vec(
                kt.shape().clone(),
                kt.data().iter().map(|&v| v >> shift).collect(),
            );
            let want = convolve_s8_acc(&x, &kt_trunc, &bias, off, &geom);
            let mut cols = Vec::new();
            let mut got = vec![0i32; want.numel()];
            convolve_s8_fast_shifted(&x, &kt, &bias, off, shift, &geom, &mut cols, &mut got, |a, _| a);
            if got != *want.data() {
                return Err(format!("conv rung mismatch (shift {shift})"));
            }
            // Depthwise on the same rung.
            let c = cin;
            let kd = Tensor::from_vec(Shape::new(&[c, 3, 3]), rand_i8(rng, c * 9, -127, 127));
            let kd_trunc =
                Tensor::from_vec(kd.shape().clone(), kd.data().iter().map(|&v| v >> shift).collect());
            let dbias: Vec<i32> = (0..c).map(|_| rng.int_range(-2000, 2000) as i32).collect();
            let dwant = dwconv_s8_acc(&x, &kd_trunc, &dbias, off, &geom);
            let (mut wt, mut acc_row) = (Vec::new(), Vec::new());
            let mut dgot = vec![0i32; dwant.numel()];
            dwconv_s8_fast_shifted(
                &x, &kd, &dbias, off, shift, &geom, &mut wt, &mut acc_row, &mut dgot, |a, _| a,
            );
            if dgot != *dwant.data() {
                return Err(format!("dwconv rung mismatch (shift {shift})"));
            }
            // Fully connected: per-rung row sums, naive fed truncated weights.
            let d = rng.int_range(1, 64) as usize;
            let hh = rng.int_range(1, 12) as usize;
            let fx = rand_i8(rng, d, -128, 127);
            let fw = Tensor::from_vec(Shape::new(&[hh, d]), rand_i8(rng, hh * d, -127, 127));
            let fw_trunc =
                Tensor::from_vec(fw.shape().clone(), fw.data().iter().map(|&v| v >> shift).collect());
            let fbias: Vec<i32> = (0..hh).map(|_| rng.int_range(-5000, 5000) as i32).collect();
            let fwant = fully_connected_s8_acc(&fx, &fw_trunc, &fbias, off);
            let sums = weight_row_sums_shifted(&fw, shift);
            let mut fgot = vec![0i32; hh];
            fully_connected_s8_fast_shifted(&fx, &fw, &fbias, &sums, off, shift, &mut fgot, |a, _| a);
            if fgot != fwant {
                return Err(format!("fc rung mismatch (shift {shift})"));
            }
            Ok(())
        });
    }

    #[test]
    fn im2col_s8_identity_for_1x1() {
        let x = Tensor::from_vec(Shape::hwc(2, 3, 2), vec![1i8, -2, 3, -4, 5, -6, 7, -8, 9, -10, 11, -12]);
        let mut cols = Vec::new();
        let (m, k) = im2col_s8(&x, &ConvGeom::new(1, 1, 1, 0), 10, &mut cols);
        assert_eq!((m, k), (6, 2));
        let want: Vec<i32> = x.data().iter().map(|&v| v as i32 + 10).collect();
        assert_eq!(cols, want);
    }
}
