//! `arm_convolve_s8` port: int8 NHWC convolution with int32 accumulation.
//!
//! Semantics match CMSIS-NN: the input carries an `input_offset` added to
//! every element (CMSIS convention: `input_offset = −z_in`, so the addition
//! recovers the real-valued zero alignment), weights are symmetric int8
//! (no offset), bias is int32 (already folded to `s_in·s_w` scale), and
//! each accumulator is requantized per [`super::Requant`].

use super::requant::Requant;
use crate::tensor::{ConvGeom, Shape, Tensor};

/// int8 convolution: `input` HWC, `kernel` OHWI, `bias` per output channel.
pub fn convolve_s8(
    input: &Tensor<i8>,
    kernel: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
    requant: &Requant,
    geom: &ConvGeom,
) -> Tensor<i8> {
    let acc = convolve_s8_acc(input, kernel, bias, input_offset, geom);
    let cout = kernel.shape().dim(0);
    let mut out = Tensor::zeros(acc.shape().clone());
    requant.apply_slice(acc.data(), out.data_mut(), cout);
    out
}

/// The wide (int32) convolution — the shared core. Dynamic requantization
/// needs this buffer in full (that's exactly the §3 `b′·h` memory cost),
/// static/PDQ call it through [`convolve_s8`] which requantizes each entry
/// immediately (in a real MCU kernel the buffer never materializes; here
/// the split keeps the code paths identical and testable).
pub fn convolve_s8_acc(
    input: &Tensor<i8>,
    kernel: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
    geom: &ConvGeom,
) -> Tensor<i32> {
    let (h, w, cin) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    let (cout, kh, kw, kcin) =
        (kernel.shape().dim(0), kernel.shape().dim(1), kernel.shape().dim(2), kernel.shape().dim(3));
    assert_eq!(cin, kcin, "channel mismatch");
    assert_eq!(bias.len(), cout);
    assert_eq!((kh, kw), (geom.kh, geom.kw));
    let (oh, ow) = geom.out_dims(h, w);
    let mut out = Tensor::zeros(Shape::hwc(oh, ow, cout));
    let xd = input.data();
    let kd = kernel.data();
    let od = out.data_mut();
    for oy in 0..oh {
        let y_origin = (oy * geom.stride) as isize - geom.pad as isize;
        for ox in 0..ow {
            let x_origin = (ox * geom.stride) as isize - geom.pad as isize;
            let obase = (oy * ow + ox) * cout;
            for v in 0..cout {
                let mut acc = bias[v];
                let kbase = v * kh * kw * cin;
                for dy in 0..kh {
                    let yy = y_origin + dy as isize;
                    if yy < 0 || yy >= h as isize {
                        continue; // zero padding: contributes nothing since
                                  // CMSIS folds the pad into the bias term
                    }
                    for dx in 0..kw {
                        let xx = x_origin + dx as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        let xrow = (yy as usize * w + xx as usize) * cin;
                        let krow = kbase + (dy * kw + dx) * cin;
                        for c in 0..cin {
                            acc += (xd[xrow + c] as i32 + input_offset) * kd[krow + c] as i32;
                        }
                    }
                }
                od[obase + v] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ops;
    use crate::quant::affine::{dequantize, quantize};
    use crate::quant::QParams;
    use crate::util::check::Checker;
    use crate::util::Pcg32;

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 identity kernel, no offsets, unity requant.
        let input = Tensor::from_vec(Shape::hwc(2, 2, 1), vec![1i8, -2, 3, -4]);
        let kernel = Tensor::from_vec(Shape::ohwi(1, 1, 1, 1), vec![1i8]);
        let r = Requant::per_tensor(1.0, 0);
        let out = convolve_s8(&input, &kernel, &[0], 0, &r, &ConvGeom::new(1, 1, 1, 0));
        assert_eq!(out.data(), input.data());
    }

    #[test]
    fn input_offset_applied() {
        let input = Tensor::from_vec(Shape::hwc(1, 1, 1), vec![10i8]);
        let kernel = Tensor::from_vec(Shape::ohwi(1, 1, 1, 1), vec![2i8]);
        let r = Requant::per_tensor(1.0, 0);
        // (10 + 5) * 2 = 30
        let out = convolve_s8(&input, &kernel, &[0], 5, &r, &ConvGeom::new(1, 1, 1, 0));
        assert_eq!(out.data(), &[30]);
    }

    #[test]
    fn bias_added_before_requant() {
        let input = Tensor::from_vec(Shape::hwc(1, 1, 1), vec![0i8]);
        let kernel = Tensor::from_vec(Shape::ohwi(1, 1, 1, 1), vec![1i8]);
        let r = Requant::per_tensor(0.5, 0);
        let out = convolve_s8(&input, &kernel, &[100], 0, &r, &ConvGeom::new(1, 1, 1, 0));
        assert_eq!(out.data(), &[50]);
    }

    /// Full quantized conv vs the float oracle: quantize inputs/weights,
    /// run int8 conv with proper effective scales, dequantize, compare.
    #[test]
    fn matches_float_conv_through_quantization() {
        Checker::new(0xCC, 20).check("int8 conv ~ float conv", |rng| {
            let h = rng.int_range(4, 10) as usize;
            let w = rng.int_range(4, 10) as usize;
            let cin = rng.int_range(1, 6) as usize;
            let cout = rng.int_range(1, 6) as usize;
            let k = 3usize;
            let geom = ConvGeom::same(k, 1);
            // Float data.
            let x: Vec<f32> = (0..h * w * cin).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
            let wts: Vec<f32> =
                (0..cout * k * k * cin).map(|_| rng.normal_ms(0.0, 0.2)).collect();
            let xt = Tensor::from_vec(Shape::hwc(h, w, cin), x.clone());
            let wt = Tensor::from_vec(Shape::ohwi(cout, k, k, cin), wts.clone());
            let want = ops::conv2d(&xt, &wt, &vec![0.0; cout], &geom);
            // Quantize input (asymmetric) and weights (symmetric per-tensor).
            let qp_in = QParams::from_range(-1.0, 1.0, 8);
            let xq: Vec<i8> = x
                .iter()
                .map(|&v| (quantize(v, &qp_in) - 128).clamp(-128, 127) as i8)
                .collect();
            let w_absmax = wts.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-6);
            let s_w = w_absmax / 127.0;
            let wq: Vec<i8> = wts.iter().map(|&v| (v / s_w).round().clamp(-127.0, 127.0) as i8).collect();
            // Output range from the float oracle (dynamic-style for the test).
            let (lo, hi) = crate::util::stats::min_max(want.data());
            let qp_out = QParams::from_range(lo, hi, 8);
            let s_out = qp_out.scale;
            // CMSIS wiring: input_offset = -(z_in in signed space).
            // Our signed value is q_u - 128 where q_u = round(x/s)+z+128, so
            // real x = s_in * (q_s - (z_in + 2^{b-1} - 128)) = s_in*(q_s - z_s)
            let z_s = qp_in.zero_point; // signed-space zero offset
            let eff = qp_in.scale as f64 * s_w as f64 / s_out as f64;
            let z_out_s = qp_out.zero_point; // signed-space output zero
            let r = Requant::per_tensor(eff, z_out_s);
            let xqt = Tensor::from_vec(Shape::hwc(h, w, cin), xq);
            let wqt = Tensor::from_vec(Shape::ohwi(cout, k, k, cin), wq);
            let out = convolve_s8(&xqt, &wqt, &vec![0i32; cout], -z_s, &r, &geom);
            // Dequantize int8 output: real = s_out * (q - z_out_s)  [signed]
            for (i, (&q, &f)) in out.data().iter().zip(want.data().iter()).enumerate() {
                let deq = s_out * (q as i32 - z_out_s) as f32;
                let tol = 3.0 * s_out + 2.0 * qp_in.scale * (k * k * cin) as f32 * s_w;
                if (deq - f).abs() > tol {
                    return Err(format!("[{i}]: int8 {deq} vs float {f} (tol {tol})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dequantize_helper_consistency() {
        // Anchor the signed-space convention used in the big test above.
        let qp = QParams::from_range(-1.0, 1.0, 8);
        let q_u = quantize(0.5, &qp);
        let q_s = q_u - 128;
        let deq_signed = qp.scale * (q_s - qp.zero_point) as f32;
        assert!((deq_signed - dequantize(q_u, &qp)).abs() < 1e-6);
    }
}
