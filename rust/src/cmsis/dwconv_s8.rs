//! `arm_depthwise_conv_s8` port (multiplier 1): int8 depthwise convolution.

use super::requant::Requant;
use crate::tensor::{ConvGeom, Shape, Tensor};

/// Depthwise int8 conv: `input` HWC, `kernel` `[C, kh, kw]`.
pub fn dwconv_s8(
    input: &Tensor<i8>,
    kernel: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
    requant: &Requant,
    geom: &ConvGeom,
) -> Tensor<i8> {
    let acc = dwconv_s8_acc(input, kernel, bias, input_offset, geom);
    let c = kernel.shape().dim(0);
    let mut out = Tensor::zeros(acc.shape().clone());
    requant.apply_slice(acc.data(), out.data_mut(), c);
    out
}

/// Wide accumulator variant (for the dynamic wrapper).
pub fn dwconv_s8_acc(
    input: &Tensor<i8>,
    kernel: &Tensor<i8>,
    bias: &[i32],
    input_offset: i32,
    geom: &ConvGeom,
) -> Tensor<i32> {
    let (h, w, c) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    let (kc, kh, kw) = (kernel.shape().dim(0), kernel.shape().dim(1), kernel.shape().dim(2));
    assert_eq!(c, kc, "dwconv channel mismatch");
    assert_eq!(bias.len(), c);
    let (oh, ow) = geom.out_dims(h, w);
    let mut out = Tensor::zeros(Shape::hwc(oh, ow, c));
    for oy in 0..oh {
        let y_origin = (oy * geom.stride) as isize - geom.pad as isize;
        for ox in 0..ow {
            let x_origin = (ox * geom.stride) as isize - geom.pad as isize;
            for ch in 0..c {
                let mut acc = bias[ch];
                for dy in 0..kh {
                    let yy = y_origin + dy as isize;
                    if yy < 0 || yy >= h as isize {
                        continue;
                    }
                    for dx in 0..kw {
                        let xx = x_origin + dx as isize;
                        if xx < 0 || xx >= w as isize {
                            continue;
                        }
                        acc += (input.at(&[yy as usize, xx as usize, ch]) as i32 + input_offset)
                            * kernel.at(&[ch, dy, dx]) as i32;
                    }
                }
                out.set(&[oy, ox, ch], acc);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ops;
    use crate::util::check::Checker;

    #[test]
    fn channels_do_not_mix() {
        let input = Tensor::from_vec(Shape::hwc(1, 1, 2), vec![10i8, 20]);
        let kernel = Tensor::from_vec(Shape::new(&[2, 1, 1]), vec![1i8, 2]);
        let r = Requant::per_tensor(1.0, 0);
        let out = dwconv_s8(&input, &kernel, &[0, 0], 0, &r, &ConvGeom::new(1, 1, 1, 0));
        assert_eq!(out.data(), &[10, 40]);
    }

    #[test]
    fn matches_float_dwconv() {
        Checker::new(0xDD, 15).check("int8 dwconv ~ float", |rng| {
            let h = rng.int_range(4, 8) as usize;
            let w = rng.int_range(4, 8) as usize;
            let c = rng.int_range(1, 6) as usize;
            let geom = ConvGeom::same(3, 1);
            // Use integer-valued floats so the comparison is exact.
            let x: Vec<i8> = (0..h * w * c).map(|_| rng.int_range(-50, 50) as i8).collect();
            let k: Vec<i8> = (0..c * 9).map(|_| rng.int_range(-4, 4) as i8).collect();
            let bias: Vec<i32> = (0..c).map(|_| rng.int_range(-100, 100) as i32).collect();
            let xf = Tensor::from_vec(Shape::hwc(h, w, c), x.iter().map(|&v| v as f32).collect());
            let kf = Tensor::from_vec(
                Shape::new(&[c, 3, 3]),
                k.iter().map(|&v| v as f32).collect(),
            );
            let want = ops::dwconv2d(&xf, &kf, &bias.iter().map(|&b| b as f32).collect::<Vec<_>>(), &geom);
            let xq = Tensor::from_vec(Shape::hwc(h, w, c), x);
            let kq = Tensor::from_vec(Shape::new(&[c, 3, 3]), k);
            let acc = dwconv_s8_acc(&xq, &kq, &bias, 0, &geom);
            for (i, (&a, &f)) in acc.data().iter().zip(want.data().iter()).enumerate() {
                if a != f as i32 {
                    return Err(format!("[{i}]: {a} vs {f}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn requant_clamps_to_int8() {
        let input = Tensor::from_vec(Shape::hwc(1, 1, 1), vec![100i8]);
        let kernel = Tensor::from_vec(Shape::new(&[1, 1, 1]), vec![100i8]);
        let r = Requant::per_tensor(1.0, 0);
        let out = dwconv_s8(&input, &kernel, &[0], 0, &r, &ConvGeom::new(1, 1, 1, 0));
        assert_eq!(out.data(), &[127]); // 10000 clamps
    }
}
