//! Requantization of int32 accumulators to int8 (CMSIS `arm_nn_requantize`).

use crate::quant::fixedpoint::FixedMultiplier;

/// Requantization spec: per-channel (or broadcast per-tensor) multipliers,
/// output zero offset and activation clamp window.
#[derive(Clone, Debug)]
pub struct Requant {
    /// One multiplier per output channel, or exactly one for per-tensor.
    pub multipliers: Vec<FixedMultiplier>,
    /// Added after scaling (the output zero-point in signed-int8 space).
    pub output_offset: i32,
    pub act_min: i32,
    pub act_max: i32,
}

impl Requant {
    /// Per-tensor spec from an effective scale `s_in·s_w / s_out`.
    pub fn per_tensor(effective_scale: f64, output_offset: i32) -> Self {
        Self {
            multipliers: vec![FixedMultiplier::from_scale(effective_scale)],
            output_offset,
            act_min: i8::MIN as i32,
            act_max: i8::MAX as i32,
        }
    }

    /// Per-channel spec.
    pub fn per_channel(effective_scales: &[f64], output_offset: i32) -> Self {
        Self {
            multipliers: effective_scales.iter().map(|&s| FixedMultiplier::from_scale(s)).collect(),
            output_offset,
            act_min: i8::MIN as i32,
            act_max: i8::MAX as i32,
        }
    }

    /// Restrict the activation window (fused ReLU on the int8 grid).
    pub fn with_activation(mut self, act_min: i32, act_max: i32) -> Self {
        self.act_min = act_min;
        self.act_max = act_max;
        self
    }

    /// Requantize one accumulator for channel `ch`.
    #[inline]
    pub fn apply(&self, acc: i32, ch: usize) -> i8 {
        let m = if self.multipliers.len() == 1 { &self.multipliers[0] } else { &self.multipliers[ch] };
        let v = m.apply(acc) + self.output_offset;
        v.clamp(self.act_min, self.act_max) as i8
    }

    /// Requantize a whole channels-last accumulator tensor (`pixels ×
    /// channels` row-major). Iterates pixel rows and channels directly, so
    /// the per-element `i % channels` of the scalar loop disappears.
    pub fn apply_slice(&self, acc: &[i32], out: &mut [i8], channels: usize) {
        assert_eq!(acc.len(), out.len(), "requant: acc/out length mismatch");
        assert!(channels > 0 && acc.len() % channels == 0, "requant: not channel-aligned");
        if self.multipliers.len() == 1 {
            let m = self.multipliers[0];
            for (&a, o) in acc.iter().zip(out.iter_mut()) {
                let v = m.apply(a) + self.output_offset;
                *o = v.clamp(self.act_min, self.act_max) as i8;
            }
        } else {
            assert_eq!(self.multipliers.len(), channels, "requant: channel arity");
            for (arow, orow) in acc.chunks_exact(channels).zip(out.chunks_exact_mut(channels)) {
                for ch in 0..channels {
                    let v = self.multipliers[ch].apply(arow[ch]) + self.output_offset;
                    orow[ch] = v.clamp(self.act_min, self.act_max) as i8;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_tensor_broadcasts() {
        let r = Requant::per_tensor(0.5, 0);
        assert_eq!(r.apply(10, 0), 5);
        assert_eq!(r.apply(10, 7), 5); // any channel, same multiplier
    }

    #[test]
    fn per_channel_selects() {
        let r = Requant::per_channel(&[1.0, 0.1], 0);
        assert_eq!(r.apply(50, 0), 50);
        assert_eq!(r.apply(50, 1), 5);
    }

    #[test]
    fn offset_and_clamp() {
        let r = Requant::per_tensor(1.0, 100);
        assert_eq!(r.apply(50, 0), 127); // 150 clamps to int8 max
        assert_eq!(r.apply(-300, 0), -128);
    }

    #[test]
    fn fused_relu_window() {
        let r = Requant::per_tensor(1.0, 0).with_activation(0, 127);
        assert_eq!(r.apply(-5, 0), 0);
        assert_eq!(r.apply(5, 0), 5);
    }

    #[test]
    fn apply_slice_matches_scalar() {
        // 3 pixels × 2 channels, per-channel multipliers: the hoisted loop
        // must agree element-for-element with the modulo-indexed scalar path.
        let r = Requant::per_channel(&[1.0, 0.25], 3).with_activation(-100, 100);
        let acc = [40i32, 40, -500, 8, 120, -8];
        let mut out = [0i8; 6];
        r.apply_slice(&acc, &mut out, 2);
        for (i, (&a, &o)) in acc.iter().zip(out.iter()).enumerate() {
            assert_eq!(o, r.apply(a, i % 2), "[{i}]");
        }
        let rt = Requant::per_tensor(0.5, -1);
        let mut out_t = [0i8; 6];
        rt.apply_slice(&acc, &mut out_t, 2);
        for (i, (&a, &o)) in acc.iter().zip(out_t.iter()).enumerate() {
            assert_eq!(o, rt.apply(a, i % 2), "[{i}]");
        }
    }
}
