//! Requantization of int32 accumulators to int8 (CMSIS `arm_nn_requantize`).

use crate::quant::fixedpoint::FixedMultiplier;

/// Requantization spec: per-channel (or broadcast per-tensor) multipliers,
/// output zero offset and activation clamp window.
#[derive(Clone, Debug)]
pub struct Requant {
    /// One multiplier per output channel, or exactly one for per-tensor.
    pub multipliers: Vec<FixedMultiplier>,
    /// Added after scaling (the output zero-point in signed-int8 space).
    pub output_offset: i32,
    pub act_min: i32,
    pub act_max: i32,
}

impl Requant {
    /// Per-tensor spec from an effective scale `s_in·s_w / s_out`.
    pub fn per_tensor(effective_scale: f64, output_offset: i32) -> Self {
        Self {
            multipliers: vec![FixedMultiplier::from_scale(effective_scale)],
            output_offset,
            act_min: i8::MIN as i32,
            act_max: i8::MAX as i32,
        }
    }

    /// Per-channel spec.
    pub fn per_channel(effective_scales: &[f64], output_offset: i32) -> Self {
        Self {
            multipliers: effective_scales.iter().map(|&s| FixedMultiplier::from_scale(s)).collect(),
            output_offset,
            act_min: i8::MIN as i32,
            act_max: i8::MAX as i32,
        }
    }

    /// Restrict the activation window (fused ReLU on the int8 grid).
    pub fn with_activation(mut self, act_min: i32, act_max: i32) -> Self {
        self.act_min = act_min;
        self.act_max = act_max;
        self
    }

    /// Requantize one accumulator for channel `ch`.
    #[inline]
    pub fn apply(&self, acc: i32, ch: usize) -> i8 {
        let m = if self.multipliers.len() == 1 { &self.multipliers[0] } else { &self.multipliers[ch] };
        let v = m.apply(acc) + self.output_offset;
        v.clamp(self.act_min, self.act_max) as i8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_tensor_broadcasts() {
        let r = Requant::per_tensor(0.5, 0);
        assert_eq!(r.apply(10, 0), 5);
        assert_eq!(r.apply(10, 7), 5); // any channel, same multiplier
    }

    #[test]
    fn per_channel_selects() {
        let r = Requant::per_channel(&[1.0, 0.1], 0);
        assert_eq!(r.apply(50, 0), 50);
        assert_eq!(r.apply(50, 1), 5);
    }

    #[test]
    fn offset_and_clamp() {
        let r = Requant::per_tensor(1.0, 100);
        assert_eq!(r.apply(50, 0), 127); // 150 clamps to int8 max
        assert_eq!(r.apply(-300, 0), -128);
    }

    #[test]
    fn fused_relu_window() {
        let r = Requant::per_tensor(1.0, 0).with_activation(0, 127);
        assert_eq!(r.apply(-5, 0), 0);
        assert_eq!(r.apply(5, 0), 5);
    }
}
