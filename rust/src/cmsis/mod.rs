//! True-int8 inference kernels mirroring CMSIS-NN (paper §5.1).
//!
//! The paper's on-device implementation wraps `arm_convolve_s8` and
//! `arm_fully_connected_s8`; this module is a faithful Rust port of those
//! kernels' semantics — int8 operands, int32 accumulators, symmetric int8
//! weights (no weight offset), per-channel Q31 requantization multipliers,
//! output offset, activation clamping — plus the paper's wrappers that
//! bolt the three requantization strategies on top:
//!
//! - [`pdq_wrappers::conv_static`] — precomputed requant (Fig. 1-a);
//! - [`pdq_wrappers::conv_dynamic`] — buffer the int32 output, scan its
//!   range, then requantize (Fig. 1-b; the `b′·h` memory cost of §3);
//! - [`pdq_wrappers::conv_pdq`] — run the integer-only estimator
//!   ([`crate::estimator::fixed`]) on the input first, derive the output
//!   grid from `I(α,β)`, then convolve straight to int8 (Fig. 1-c).
//!
//! All arithmetic on the estimation path is fixed-point (Newton–Raphson
//! integer sqrt), exactly as on the STM32 target.
//!
//! [`fast`] holds the serving-speed versions of the same kernels — im2col +
//! register-blocked i8×i8→i32 GEMM with the requantize fused into the
//! accumulator sweep — used by [`crate::nn::int8_exec::Int8Executor`]. The
//! scalar ports above are their bit-exact oracle.

pub mod convolve_s8;
pub mod dwconv_s8;
pub mod fast;
pub mod fully_connected_s8;
pub mod pdq_wrappers;
pub mod requant;

pub use convolve_s8::convolve_s8;
pub use dwconv_s8::dwconv_s8;
pub use fully_connected_s8::fully_connected_s8;
pub use requant::Requant;
