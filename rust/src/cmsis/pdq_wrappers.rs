//! The paper's CMSIS wrappers (§5.1): estimate-then-convolve.
//!
//! Three requantization strategies around the same int8 kernels:
//!
//! - **static** — output grid fixed at deploy time; the kernel requantizes
//!   each accumulator immediately (O(1) extra memory).
//! - **dynamic** — the full int32 accumulator tensor is buffered, its range
//!   scanned, the output grid derived, then the buffer requantized
//!   (O(b′·h) extra memory — §3).
//! - **pdq (ours)** — the integer-only estimator predicts the output grid
//!   from the *input* (γ-strided window sums → Q16.16 moments →
//!   Newton–Raphson σ → `I(α,β)`), then the kernel requantizes immediately,
//!   like static (O(1) extra memory, §4.2's 2b′ on top of static).

use super::convolve_s8::{convolve_s8, convolve_s8_acc};
use super::requant::Requant;
use crate::estimator::fixed::{FixedEstimator, WindowStats};
use crate::estimator::IntervalSpec;
use crate::tensor::{ConvGeom, Tensor};
#[cfg(test)]
use crate::tensor::Shape;

/// Output quantization in signed-int8 space: `real = scale · (q − zero)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QOut {
    pub scale: f32,
    pub zero: i32,
}

impl QOut {
    /// From a real-valued dynamic range.
    pub fn from_range(lo: f32, hi: f32) -> Self {
        let (lo, hi) = if lo <= hi { (lo, hi) } else { (hi, lo) };
        let span = (hi - lo).max(1e-9);
        let scale = span / 255.0;
        let zero = (-128.0 - lo / scale).round() as i32;
        Self { scale, zero }
    }

    /// Dequantize one output value.
    pub fn dequant(&self, q: i8) -> f32 {
        self.scale * (q as i32 - self.zero) as f32
    }
}

/// A deploy-ready int8 conv layer: quantized kernel, folded bias, weight
/// statistics for the estimator, calibrated interval.
#[derive(Clone, Debug)]
pub struct ConvLayerS8 {
    pub kernel: Tensor<i8>,
    pub bias: Vec<i32>,
    pub geom: ConvGeom,
    /// Symmetric per-tensor weight scale.
    pub s_w: f32,
    /// Surrogate stats of the *dequantized* weights (what actually runs).
    pub mu_w: f32,
    pub var_w: f32,
    pub interval: IntervalSpec,
}

impl ConvLayerS8 {
    /// Quantize a float conv layer for deployment. `s_in` is needed to fold
    /// the float bias into the int32 accumulator scale `s_in·s_w`.
    pub fn from_float(w: &Tensor<f32>, bias_f: &[f32], geom: ConvGeom, s_in: f32) -> Self {
        let absmax = w.data().iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-9);
        let s_w = absmax / 127.0;
        let kernel = w.map(|v| (v / s_w).round().clamp(-127.0, 127.0) as i8);
        let acc_scale = s_in * s_w;
        let bias = bias_f.iter().map(|&b| (b / acc_scale).round() as i32).collect();
        // Stats of the dequantized weights.
        let deq: Vec<f32> = kernel.data().iter().map(|&q| q as f32 * s_w).collect();
        let mu_w = crate::util::stats::mean(&deq);
        let var_w = crate::util::stats::variance(&deq);
        Self { kernel, bias, geom, s_w, mu_w, var_w, interval: IntervalSpec::default() }
    }

    fn cout(&self) -> usize {
        self.kernel.shape().dim(0)
    }
}

/// Static wrapper: grid known beforehand, single fused pass.
pub fn conv_static(
    layer: &ConvLayerS8,
    input: &Tensor<i8>,
    s_in: f32,
    z_in: i32,
    out: QOut,
) -> Tensor<i8> {
    let eff = s_in as f64 * layer.s_w as f64 / out.scale as f64;
    let r = Requant::per_tensor(eff, out.zero);
    convolve_s8(input, &layer.kernel, &layer.bias, -z_in, &r, &layer.geom)
}

/// Dynamic wrapper: buffer wide accumulators, scan, requantize
/// (Fig. 1-b — pays `b′·h` working memory).
pub fn conv_dynamic(
    layer: &ConvLayerS8,
    input: &Tensor<i8>,
    s_in: f32,
    z_in: i32,
) -> (Tensor<i8>, QOut) {
    let acc = convolve_s8_acc(input, &layer.kernel, &layer.bias, -z_in, &layer.geom);
    // Accumulators live on the s_in·s_w grid.
    let acc_scale = s_in * layer.s_w;
    let mut lo = i32::MAX;
    let mut hi = i32::MIN;
    for &a in acc.data() {
        lo = lo.min(a);
        hi = hi.max(a);
    }
    let out = QOut::from_range(lo as f32 * acc_scale, hi as f32 * acc_scale);
    let eff = acc_scale as f64 / out.scale as f64;
    let r = Requant::per_tensor(eff, out.zero);
    let cout = layer.cout();
    let mut q = Tensor::zeros(acc.shape().clone());
    r.apply_slice(acc.data(), q.data_mut(), cout);
    (q, out)
}

/// PDQ wrapper (ours): integer estimation first, then a fused static-style
/// pass with the predicted grid (Fig. 1-c).
pub fn conv_pdq(
    layer: &ConvLayerS8,
    input: &Tensor<i8>,
    s_in: f32,
    z_in: i32,
    gamma: usize,
) -> (Tensor<i8>, QOut) {
    let (s1, s2) = int_window_sums(input, &layer.geom, z_in, gamma);
    let est = FixedEstimator::new(layer.mu_w, layer.var_w, s_in);
    let m = est.from_window_sums(&s1, &s2).to_moments();
    let (lo, hi) = layer.interval.range(&m);
    let out = QOut::from_range(lo, hi);
    (conv_static(layer, input, s_in, z_in, out), out)
}

/// γ-strided integer window sums over the conv's receptive fields — the
/// estimation stage the MCU runs (O(HW·p·k·k'/γ²), §4.2). Exactly mirrors
/// the float [`crate::estimator::conv::window_sums_naive`].
pub fn int_window_sums(
    input: &Tensor<i8>,
    geom: &ConvGeom,
    z_in: i32,
    gamma: usize,
) -> (Vec<i64>, Vec<i64>) {
    assert!(gamma >= 1);
    let (h, w, c) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    let (oh, ow) = geom.out_dims(h, w);
    let xd = input.data();
    let mut s1 = Vec::new();
    let mut s2 = Vec::new();
    let mut oy = 0;
    while oy < oh {
        let (y0, y1) = geom.in_range_y(oy, h);
        let mut ox = 0;
        while ox < ow {
            let (x0, x1) = geom.in_range_x(ox, w);
            let mut a = 0i64;
            let mut b = 0i64;
            for yy in y0..y1 {
                let row = (yy * w) * c;
                for xx in x0..x1 {
                    let base = row + xx * c;
                    for ch in 0..c {
                        let d = (xd[base + ch] as i32 - z_in) as i64;
                        a += d;
                        b += d * d;
                    }
                }
            }
            s1.push(a);
            s2.push(b);
            ox += gamma;
        }
        oy += gamma;
    }
    (s1, s2)
}

/// Streaming variant of [`int_window_sums`]: folds every γ-sampled window's
/// `(S1, S2)` straight into a [`WindowStats`] accumulator instead of
/// materializing the per-window vectors — the estimation pass the int8
/// executor runs, whose working memory is 4 integer registers (§4.2).
pub fn conv_window_stats(
    input: &Tensor<i8>,
    geom: &ConvGeom,
    z_in: i32,
    gamma: usize,
) -> WindowStats {
    assert!(gamma >= 1);
    let (h, w, c) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    let (oh, ow) = geom.out_dims(h, w);
    let xd = input.data();
    let mut st = WindowStats::default();
    let mut oy = 0;
    while oy < oh {
        let (y0, y1) = geom.in_range_y(oy, h);
        let mut ox = 0;
        while ox < ow {
            let (x0, x1) = geom.in_range_x(ox, w);
            let mut a = 0i64;
            let mut b = 0i64;
            for yy in y0..y1 {
                let row = (yy * w) * c;
                for xx in x0..x1 {
                    let base = row + xx * c;
                    for ch in 0..c {
                        let d = (xd[base + ch] as i32 - z_in) as i64;
                        a += d;
                        b += d * d;
                    }
                }
            }
            st.push(a, b);
            ox += gamma;
        }
        oy += gamma;
    }
    st
}

/// Depthwise analogue of [`conv_window_stats`]: each output entry `(i, j, v)`
/// sees only channel `v` of its receptive field, so the sampled population
/// is (position × channel) with channel-restricted window sums. Paired with
/// the layer's *global* depthwise weight statistics this is the shared-σ²
/// simplification of §4.1 applied to the integer path.
pub fn dw_window_stats(
    input: &Tensor<i8>,
    geom: &ConvGeom,
    z_in: i32,
    gamma: usize,
) -> WindowStats {
    assert!(gamma >= 1);
    let (h, w, c) = (input.shape().dim(0), input.shape().dim(1), input.shape().dim(2));
    let (oh, ow) = geom.out_dims(h, w);
    let xd = input.data();
    let mut st = WindowStats::default();
    let mut oy = 0;
    while oy < oh {
        let (y0, y1) = geom.in_range_y(oy, h);
        let mut ox = 0;
        while ox < ow {
            let (x0, x1) = geom.in_range_x(ox, w);
            for ch in 0..c {
                let mut a = 0i64;
                let mut b = 0i64;
                for yy in y0..y1 {
                    let row = (yy * w) * c;
                    for xx in x0..x1 {
                        let d = (xd[row + xx * c + ch] as i32 - z_in) as i64;
                        a += d;
                        b += d * d;
                    }
                }
                st.push(a, b);
            }
            ox += gamma;
        }
        oy += gamma;
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ops;
    use crate::util::Pcg32;

    /// Build a random float conv layer + int8 input and return everything
    /// needed to cross-check against the float oracle.
    fn setup(rng: &mut Pcg32, h: usize, w: usize, cin: usize, cout: usize) -> (ConvLayerS8, Tensor<i8>, Tensor<f32>, f32, i32) {
        let geom = ConvGeom::same(3, 1);
        let wts: Vec<f32> = (0..cout * 9 * cin).map(|_| rng.normal_ms(0.02, 0.15)).collect();
        let bias: Vec<f32> = (0..cout).map(|_| rng.uniform_range(-0.1, 0.1)).collect();
        let wt = Tensor::from_vec(Shape::ohwi(cout, 3, 3, cin), wts);
        // Input on a [0,1] grid quantized to signed int8: s=1/255, z=-128.
        let s_in = 1.0 / 255.0;
        let z_in = -128i32;
        let xq: Vec<i8> = (0..h * w * cin)
            .map(|_| ((rng.uniform() * 255.0).round() as i32 - 128).clamp(-128, 127) as i8)
            .collect();
        let layer = ConvLayerS8::from_float(&wt, &bias, geom, s_in);
        let xqt = Tensor::from_vec(Shape::hwc(h, w, cin), xq.clone());
        // Float oracle input = dequantized int8 input; weights = dequantized kernel.
        let xf = Tensor::from_vec(
            Shape::hwc(h, w, cin),
            xq.iter().map(|&q| s_in * (q as i32 - z_in) as f32).collect(),
        );
        let wf = wt.map(|v| (v / layer.s_w).round().clamp(-127.0, 127.0) * layer.s_w);
        let bias_deq: Vec<f32> = layer.bias.iter().map(|&b| b as f32 * s_in * layer.s_w).collect();
        let want = ops::conv2d(&xf, &wf, &bias_deq, &geom);
        (layer, xqt, want, s_in, z_in)
    }

    fn max_abs(data: &[f32]) -> f32 {
        data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    #[test]
    fn dynamic_wrapper_matches_oracle() {
        let mut rng = Pcg32::new(0xA1);
        let (layer, xq, want, s_in, z_in) = setup(&mut rng, 8, 8, 4, 6);
        let (out, qo) = conv_dynamic(&layer, &xq, s_in, z_in);
        for (&q, &f) in out.data().iter().zip(want.data().iter()) {
            let deq = qo.dequant(q);
            assert!((deq - f).abs() <= 2.0 * qo.scale + 1e-4, "{deq} vs {f} (s {})", qo.scale);
        }
    }

    #[test]
    fn pdq_wrapper_tracks_oracle() {
        let mut rng = Pcg32::new(0xA2);
        let (mut layer, xq, want, s_in, z_in) = setup(&mut rng, 10, 10, 4, 8);
        layer.interval = IntervalSpec { alpha: 4.0, beta: 4.0 };
        let (out, qo) = conv_pdq(&layer, &xq, s_in, z_in, 1);
        // The estimated grid must cover most of the true output mass: check
        // RMS error against the float oracle relative to the output spread.
        let mut se = 0.0f64;
        for (&q, &f) in out.data().iter().zip(want.data().iter()) {
            let deq = qo.dequant(q);
            se += ((deq - f) as f64).powi(2);
        }
        let rms = (se / want.numel() as f64).sqrt() as f32;
        let spread = max_abs(want.data()).max(1e-3);
        assert!(rms < 0.1 * spread, "rms {rms} vs spread {spread}");
    }

    #[test]
    fn pdq_gamma_sweep_consistent() {
        let mut rng = Pcg32::new(0xA3);
        let (mut layer, xq, _want, s_in, z_in) = setup(&mut rng, 16, 16, 3, 4);
        layer.interval = IntervalSpec { alpha: 4.0, beta: 4.0 };
        let (_o1, q1) = conv_pdq(&layer, &xq, s_in, z_in, 1);
        let (_o8, q8) = conv_pdq(&layer, &xq, s_in, z_in, 8);
        // Strided estimation must produce a similar grid.
        assert!((q1.scale / q8.scale).log2().abs() < 0.5, "{} vs {}", q1.scale, q8.scale);
    }

    #[test]
    fn static_wrapper_uses_given_grid() {
        let mut rng = Pcg32::new(0xA4);
        let (layer, xq, want, s_in, z_in) = setup(&mut rng, 8, 8, 3, 4);
        // Use the oracle-derived grid: static should then match dynamic.
        let (lo, hi) = crate::util::stats::min_max(want.data());
        let qo = QOut::from_range(lo, hi);
        let out = conv_static(&layer, &xq, s_in, z_in, qo);
        for (&q, &f) in out.data().iter().zip(want.data().iter()) {
            assert!((qo.dequant(q) - f).abs() <= 2.0 * qo.scale + 1e-4);
        }
    }

    #[test]
    fn int_window_sums_match_float_path() {
        let mut rng = Pcg32::new(0xA5);
        let (h, w, c) = (9, 7, 3);
        let xq: Vec<i8> = (0..h * w * c).map(|_| rng.int_range(-128, 127) as i8).collect();
        let z_in = -5i32;
        let geom = ConvGeom::same(3, 1);
        let xqt = Tensor::from_vec(Shape::hwc(h, w, c), xq.clone());
        let (s1, s2) = int_window_sums(&xqt, &geom, z_in, 2);
        // Float mirror.
        let xf = Tensor::from_vec(
            Shape::hwc(h, w, c),
            xq.iter().map(|&q| (q as i32 - z_in) as f32).collect(),
        );
        let fsums = crate::estimator::conv::window_sums_naive(&xf, &geom, 2);
        assert_eq!(s1.len(), fsums.s1.len());
        for i in 0..s1.len() {
            assert_eq!(s1[i] as f64, fsums.s1[i], "s1[{i}]");
            assert_eq!(s2[i] as f64, fsums.s2[i], "s2[{i}]");
        }
    }

    #[test]
    fn conv_window_stats_streams_int_window_sums() {
        let mut rng = Pcg32::new(0xA6);
        let (h, w, c) = (10, 8, 3);
        let xq: Vec<i8> = (0..h * w * c).map(|_| rng.int_range(-128, 127) as i8).collect();
        let xqt = Tensor::from_vec(Shape::hwc(h, w, c), xq);
        let geom = ConvGeom::same(3, 2);
        for gamma in [1usize, 2, 3] {
            let (s1, s2) = int_window_sums(&xqt, &geom, -7, gamma);
            let st = conv_window_stats(&xqt, &geom, -7, gamma);
            assert_eq!(st.n as usize, s1.len(), "γ={gamma}");
            assert_eq!(st.sum_s1, s1.iter().sum::<i64>(), "γ={gamma}");
            assert_eq!(st.sum_s2, s2.iter().sum::<i64>(), "γ={gamma}");
            assert_eq!(
                st.sum_s1_sq,
                s1.iter().map(|&a| (a as i128) * (a as i128)).sum::<i128>(),
                "γ={gamma}"
            );
        }
    }

    #[test]
    fn dw_window_stats_single_channel_degenerates_to_conv() {
        let mut rng = Pcg32::new(0xA7);
        let (h, w) = (9, 9);
        let xq: Vec<i8> = (0..h * w).map(|_| rng.int_range(-128, 127) as i8).collect();
        let xqt = Tensor::from_vec(Shape::hwc(h, w, 1), xq);
        let geom = ConvGeom::same(3, 1);
        assert_eq!(dw_window_stats(&xqt, &geom, 3, 2), conv_window_stats(&xqt, &geom, 3, 2));
    }

    #[test]
    fn qout_roundtrip() {
        let qo = QOut::from_range(-2.0, 6.0);
        assert!((qo.dequant(-128) + 2.0).abs() < qo.scale);
        assert!((qo.dequant(127) - 6.0).abs() < qo.scale);
    }
}
