//! A std-only deterministic fuzzing harness for the hostile-input
//! surfaces of the serving stack.
//!
//! Three layers:
//!
//! - **Byte mutators + grammar-aware generators** — each generator emits
//!   a plausible-but-twisted input (an HTTP request with a corrupted
//!   framing header, a wire body with attacker-shaped dims, a deeply
//!   nested JSON document), and [`run_bytes`] layers 0–3 random byte
//!   mutations on top before handing it to a target. Valid-ish inputs
//!   penetrate far deeper than pure byte noise.
//! - **Targets** — one per parser: [`target_http_request`],
//!   [`target_wire_preamble`], [`target_variant_wire`], [`target_json`],
//!   [`target_shape`], [`target_trace_header`], the artifact-format
//!   pair [`target_manifest_json`] and [`target_artifact_payload`]
//!   (corrupting a once-packed genuine `pdq-artifact-v1` blob), plus the
//!   SLO grammar pair [`target_slo_query`] and
//!   [`target_autopilot_config`] (render → parse round-trip oracles over
//!   the `/v1/slo` query and `--autopilot` spec parsers). A target
//!   panics on any violated invariant; merely
//!   returning an error is the *correct* response to hostile input.
//!   Where possible the target is differential: the HTTP target parses
//!   every input twice — one whole read vs. randomly stuttered reads
//!   with `WouldBlock` injections — and asserts identical outcomes, so
//!   resumption bugs surface without a reference implementation.
//! - **Structure-aware differential targets** — [`diff_int8_kernels`]
//!   and [`diff_int8_graphs`] drive random kernels/graphs through the
//!   fast int8 path and its scalar CMSIS oracle and assert bit-exact
//!   agreement, extending `rust/tests/int8_parity.rs` with open-ended
//!   seeded search.
//!
//! Everything is seeded [`Pcg32`]: a failure reproduces from
//! `(seed, case index)` alone, and CI can run a fixed budget as a plain
//! `cargo test` with no external fuzzing engine. The same targets are
//! wrapped by the `fuzz/` cargo-fuzz tree for coverage-guided runs on
//! machines that have libFuzzer. Every crash or mis-parse found here
//! gets a named replay in `rust/tests/fuzz_regressions.rs`.

use std::io::Read;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, OnceLock};

use crate::artifact::{
    inspect_bytes, pack_model, ArtifactEngine, Manifest, PackOptions, HEADER_LEN, MAGIC,
};
use crate::cmsis::{convolve_s8, dwconv_s8, fast, fully_connected_s8, Requant};
use crate::engine::{Engine, VariantKey, VariantSpec};
use crate::net::http::{ReadOutcome, RequestReader};
use crate::net::wire;
use crate::nn::quant_exec::{QuantExecutor, QuantSettings};
use crate::nn::{Graph, Int8Executor, QuantMode};
use crate::obs::TraceId;
use crate::quant::Granularity;
use crate::tensor::{ConvGeom, Shape, Tensor};
use crate::util::json::Json;
use crate::util::Pcg32;

// ---- driver ----------------------------------------------------------------

/// FNV-1a — a cheap stable hash for deriving per-input seeds.
fn fnv64(data: &[u8]) -> u64 {
    data.iter()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, &b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
}

/// Apply one random byte-level mutation in place.
pub fn mutate(rng: &mut Pcg32, data: &mut Vec<u8>) {
    if data.is_empty() {
        data.push(rng.next_u32() as u8);
        return;
    }
    let i = rng.below(data.len() as u32) as usize;
    match rng.below(6) {
        // Bit flip.
        0 => data[i] ^= 1 << rng.below(8),
        // Overwrite with an interesting byte (framing chars, extremes).
        1 => data[i] = *rng.choice(&[0u8, 0xFF, b'\r', b'\n', b' ', b':', b'0', b'9', 0x80]),
        // Insert a small random run.
        2 => {
            let run: Vec<u8> = (0..1 + rng.below(4)).map(|_| rng.next_u32() as u8).collect();
            data.splice(i..i, run);
        }
        // Delete a short range.
        3 => {
            let end = (i + 1 + rng.below(8) as usize).min(data.len());
            data.drain(i..end);
        }
        // Duplicate a short range (repeated headers, doubled chunks).
        4 => {
            let end = (i + 1 + rng.below(16) as usize).min(data.len());
            let dup: Vec<u8> = data[i..end].to_vec();
            data.splice(end..end, dup);
        }
        // Truncate (simulates a peer hanging up mid-message).
        _ => data.truncate(i),
    }
}

/// Run `iters` seeded cases: generate, mutate 0–3 times, feed the target.
/// A panicking case is re-raised after printing the seed, case index and a
/// hex dump, so any failure is reproducible and can be checked into
/// `fuzz_regressions.rs` verbatim.
pub fn run_bytes(
    seed: u64,
    iters: u32,
    gen: impl Fn(&mut Pcg32) -> Vec<u8>,
    target: impl Fn(&[u8]),
) {
    let mut rng = Pcg32::new(seed);
    for case in 0..iters {
        let mut data = gen(&mut rng);
        for _ in 0..rng.below(4) {
            mutate(&mut rng, &mut data);
        }
        if let Err(e) = catch_unwind(AssertUnwindSafe(|| target(&data))) {
            let hex: String = data.iter().map(|b| format!("{b:02x}")).collect();
            eprintln!("fuzz case failed: seed={seed:#x} case={case} input[{}]={hex}", data.len());
            resume_unwind(e);
        }
    }
}

// ---- generators ------------------------------------------------------------

/// Frame `body` as chunked transfer-encoding: 1–3 chunks, occasional
/// extensions and trailers — the shapes `ChunkDecoder` must accept.
fn chunk_frame(rng: &mut Pcg32, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut rest = body;
    while !rest.is_empty() {
        let take = (1 + rng.below(rest.len() as u32) as usize).min(rest.len());
        if rng.below(4) == 0 {
            out.extend_from_slice(format!("{take:x};ext={}\r\n", rng.below(100)).as_bytes());
        } else {
            out.extend_from_slice(format!("{take:x}\r\n").as_bytes());
        }
        out.extend_from_slice(&rest[..take]);
        out.extend_from_slice(b"\r\n");
        rest = &rest[take..];
    }
    out.extend_from_slice(b"0\r\n");
    if rng.below(3) == 0 {
        out.extend_from_slice(b"X-Trailer: ignored\r\n");
    }
    out.extend_from_slice(b"\r\n");
    out
}

/// A plausible-to-hostile HTTP/1.1 request: real and junk methods, paths
/// and versions, framing headers that are correct, smuggling-shaped, or
/// absent, and bodies that are raw, chunk-framed, or dangling.
pub fn gen_http_request(rng: &mut Pcg32) -> Vec<u8> {
    const METHODS: &[&str] = &["GET", "POST", "HEAD", "DELETE", "BR%OKEN", "get", ""];
    const PATHS: &[&str] = &[
        "/healthz",
        "/v1/infer",
        "/metrics?format=prometheus",
        "/../../etc/passwd",
        "/%zz%%",
        "*",
        "/v1/infer?variant=m|fp32&x=1",
    ];
    const VERSIONS: &[&str] = &["HTTP/1.1", "HTTP/1.0", "HTTP/9.9", "HTP/1.1", ""];

    let body: Vec<u8> = (0..rng.below(48)).map(|_| rng.next_u32() as u8).collect();
    let mut head =
        format!("{} {} {}\r\n", rng.choice(METHODS), rng.choice(PATHS), rng.choice(VERSIONS));

    // Exactly one framing decision, drawn from correct and hostile shapes.
    let mut wire_body = body.clone();
    match rng.below(7) {
        0 => head.push_str(&format!("Content-Length: {}\r\n", body.len())),
        1 => head.push_str(&format!("Content-Length: +{}\r\n", body.len())),
        2 => head.push_str(&format!("Content-Length : {}\r\n", body.len())),
        3 => {
            head.push_str("Transfer-Encoding: chunked\r\n");
            wire_body = chunk_frame(rng, &body);
        }
        4 => {
            // The classic smuggling pair: both framings at once.
            head.push_str("Transfer-Encoding: chunked\r\n");
            head.push_str(&format!("Content-Length: {}\r\n", body.len()));
            wire_body = chunk_frame(rng, &body);
        }
        5 => head.push_str("Transfer-Encoding: gzip\r\n"),
        // No framing header: the body bytes dangle as pipelined garbage.
        _ => {}
    }

    for _ in 0..rng.below(5) {
        match rng.below(5) {
            0 => head.push_str("Connection: close\r\n"),
            1 => head.push_str("Connection: keep-alive, close\r\n"),
            2 => head.push_str(&format!("X-Junk-{}: {}\r\n", rng.below(10), rng.next_u32())),
            3 => head.push_str(": empty-name\r\n"),
            _ => head.push_str("Host: fuzz.example\r\n"),
        }
    }
    // Occasional header bomb to probe the MAX_HEADERS cap.
    if rng.below(64) == 0 {
        for i in 0..200 {
            head.push_str(&format!("X-Bomb-{i}: x\r\n"));
        }
    }
    head.push_str("\r\n");

    let mut out = head.into_bytes();
    out.extend_from_slice(&wire_body);
    out
}

/// A valid `/v1/infer` wire body over a random small tensor and variant —
/// the mutation layer corrupts it from a realistic starting point.
pub fn gen_wire_body(rng: &mut Pcg32) -> Vec<u8> {
    let dims: Vec<usize> =
        (0..1 + rng.below(3) as usize).map(|_| 1 + rng.below(5) as usize).collect();
    let shape = Shape::new(&dims);
    let data: Vec<f32> = (0..shape.numel()).map(|_| rng.uniform_range(-4.0, 4.0)).collect();
    let img = Tensor::from_vec(shape, data);
    let spec = match rng.below(3) {
        0 => VariantSpec::Fp32,
        1 => VariantSpec::FakeQuant {
            mode: QuantMode::Probabilistic,
            gran: Granularity::PerTensor,
        },
        _ => VariantSpec::Int8 { mode: QuantMode::Dynamic, weight_gran: Granularity::PerChannel, bits: 8 },
    };
    wire::encode_infer_request(&VariantKey::new("fuzz-model", spec), rng.next_u64(), &img)
}

/// Variant wire strings: well-formed, truncated, and hostile.
pub fn gen_variant_wire(rng: &mut Pcg32) -> Vec<u8> {
    const POOL: &[&str] = &[
        "m|fp32",
        "micro_resnet|int8-ours-c",
        "m",
        "|",
        "m|",
        "|fp32",
        "m|fp32|extra",
        "café|fp32",
        "a b|fp32",
        "m|FP32",
    ];
    let mut s = rng.choice(POOL).to_string();
    if rng.below(8) == 0 {
        s = "m".repeat(1 + rng.below(200) as usize) + "|fp32";
    }
    s.into_bytes()
}

/// Random JSON documents, hostile by construction: deep nesting, escape
/// abuse, huge and tiny numbers, truncated structures (via mutation).
pub fn gen_json(rng: &mut Pcg32) -> Vec<u8> {
    if rng.below(16) == 0 {
        // Pure nesting bomb probing the parser's depth cap.
        return b"[".repeat(1 + rng.below(200) as usize);
    }
    fn node(rng: &mut Pcg32, depth: u32) -> String {
        match if depth >= 3 { rng.below(4) } else { rng.below(6) } {
            0 => format!("{}", rng.uniform_range(-1e6, 1e6)),
            1 => "null".into(),
            2 => "true".into(),
            3 => (*rng.choice(&[
                "\"plain\"",
                "\"esc\\n\\t\\\"q\\\"\"",
                "\"\\u0041\\u00e9\"",
                "\"\\ud800\"",
                "\"\\u12\"",
                "1e308",
                "-1e-308",
            ]))
            .to_string(),
            4 => {
                let items: Vec<String> =
                    (0..rng.below(4)).map(|_| node(rng, depth + 1)).collect();
                format!("[{}]", items.join(","))
            }
            _ => {
                let items: Vec<String> = (0..rng.below(4))
                    .map(|i| format!("\"k{i}\":{}", node(rng, depth + 1)))
                    .collect();
                format!("{{{}}}", items.join(","))
            }
        }
    }
    node(rng, 0).into_bytes()
}

/// Raw bytes reinterpreted as f64 shape dims by [`target_shape`]: half
/// random bit patterns, half crafted overflow/edge values.
pub fn gen_shape_dims(rng: &mut Pcg32) -> Vec<u8> {
    let mut out = Vec::new();
    for _ in 0..1 + rng.below(5) {
        let v: f64 = if rng.below(2) == 0 {
            f64::from_bits(rng.next_u64())
        } else {
            *rng.choice(&[
                8.589934592e9, // 2^33: squared overflows usize
                1e308,
                -1.0,
                0.0,
                0.5,
                3.0,
                9.007199254740992e15,
            ])
        };
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    out
}

/// `X-PDQ-Trace` header values: well-formed hex IDs plus the hostile
/// neighborhood — zero, overlong, padded, uppercase, non-hex, non-UTF-8
/// (the mutation layer adds raw byte damage on top).
pub fn gen_trace_header(rng: &mut Pcg32) -> Vec<u8> {
    match rng.below(6) {
        // A genuine minted ID, round-trip bait.
        0 => format!("{:016x}", rng.next_u64() | 1).into_bytes(),
        // Short / long hex runs straddling the 1..=16 length bound.
        1 => "f".repeat(1 + rng.below(24) as usize).into_bytes(),
        // All-zero (reserved, must be rejected) at assorted widths.
        2 => "0".repeat(1 + rng.below(20) as usize).into_bytes(),
        // Whitespace-padded and case-mixed.
        3 => format!("  {:X}\t", rng.next_u64()).into_bytes(),
        // Plausible-looking junk.
        4 => (*rng.choice(&[
            "deadbeef",
            "0x1234",
            "not-hex!",
            "1234567890abcdef0",
            "",
            "-1",
            "café",
            "1e10",
        ]))
        .to_string()
        .into_bytes(),
        // Raw bytes, frequently invalid UTF-8.
        _ => (0..rng.below(20)).map(|_| rng.next_u32() as u8).collect(),
    }
}

/// `TraceId::parse` must never panic, must reject zero, and any value it
/// accepts must survive a format → parse round trip unchanged — the
/// invariant that keeps a client-supplied `X-PDQ-Trace` queryable via
/// `GET /v1/traces?id=` exactly as echoed.
pub fn target_trace_header(data: &[u8]) {
    let Ok(s) = std::str::from_utf8(data) else { return };
    if let Some(id) = TraceId::parse(s) {
        assert_ne!(id.as_u64(), 0, "zero is reserved and must never parse");
        let printed = id.to_string();
        let back = TraceId::parse(&printed).expect("canonical form must reparse");
        assert_eq!(back, id, "trace ID drifted through format -> parse");
        assert_eq!(printed.len(), 16, "canonical form is fixed-width hex");
    }
}

// ---- byte-level targets ----------------------------------------------------

/// In-memory reader: whole-slice, or randomly stuttered with `WouldBlock`
/// injections — the same failure surface [`crate::net::chaos`] creates on
/// real sockets, without the sockets.
struct SliceReader<'a> {
    data: &'a [u8],
    pos: usize,
    /// `Some` = stutter reads (1–7 bytes) and inject `WouldBlock`.
    rng: Option<Pcg32>,
}

impl Read for SliceReader<'_> {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() || out.is_empty() {
            return Ok(0);
        }
        let mut want = out.len();
        if let Some(rng) = &mut self.rng {
            if rng.below(3) == 0 {
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            want = want.min(1 + rng.below(7) as usize);
        }
        let n = want.min(self.data.len() - self.pos);
        out[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Parse everything a reader yields; normalize to comparable strings.
fn drive_http(r: SliceReader<'_>, max_body: usize) -> (Vec<String>, String) {
    let mut reader = RequestReader::new(r, max_body);
    let mut reqs = Vec::new();
    loop {
        match reader.read_request() {
            Ok(ReadOutcome::Request(q)) => reqs.push(format!(
                "{} {} {:?} {} {:?} {:?}",
                q.method, q.path, q.query, q.version, q.headers, q.body
            )),
            Ok(ReadOutcome::Eof) => return (reqs, "eof".into()),
            Ok(ReadOutcome::Timeout { .. }) => {}
            Err(e) => return (reqs, format!("err: {e}")),
        }
    }
}

/// HTTP request parsing must (a) never panic and (b) produce *identical*
/// requests and terminal state whether the bytes arrive in one read or in
/// stuttered fragments with `WouldBlock`s between them — the resumption
/// invariant every read-timeout tick in the front door depends on.
pub fn target_http_request(data: &[u8]) {
    const MAX_BODY: usize = 4096;
    let whole = drive_http(SliceReader { data, pos: 0, rng: None }, MAX_BODY);
    let split = drive_http(
        SliceReader { data, pos: 0, rng: Some(Pcg32::new(fnv64(data))) },
        MAX_BODY,
    );
    assert_eq!(whole, split, "split reads changed the parse");
}

/// Wire bodies must decode without panicking, and anything that decodes
/// must survive an encode → decode round trip bit-exactly.
pub fn target_wire_preamble(data: &[u8]) {
    if let Ok(req) = wire::decode_infer_request(data) {
        let re = wire::encode_infer_request(&req.variant, req.id, &req.image);
        let back = wire::decode_infer_request(&re).expect("re-encoded request must decode");
        assert_eq!(back.variant, req.variant, "variant drifted through re-encode");
        assert_eq!(back.id, req.id, "id drifted through re-encode");
        assert_eq!(back.image.shape().dims(), req.image.shape().dims());
        let a: Vec<u32> = back.image.data().iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = req.image.data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "payload bits drifted through re-encode");
    }
    // The response decoder shares unframe/parse_shape; it must not panic
    // on request-shaped (or any) bytes either.
    let _ = wire::decode_infer_response(data);
}

/// Variant keys that parse must re-serialize to a wire string that parses
/// back to the same key.
pub fn target_variant_wire(data: &[u8]) {
    let Ok(s) = std::str::from_utf8(data) else { return };
    if let Ok(key) = VariantKey::parse_wire(s) {
        let w = key.wire();
        let back = VariantKey::parse_wire(&w).expect("canonical wire form must parse");
        assert_eq!(back, key, "variant key drifted through wire round trip");
    }
}

/// JSON documents that parse must serialize to a stable fixed point:
/// `serialize(parse(serialize(x))) == serialize(x)`.
pub fn target_json(data: &[u8]) {
    let Ok(s) = std::str::from_utf8(data) else { return };
    if let Ok(doc) = Json::parse(s) {
        let s1 = doc.to_string_compact();
        let doc2 = Json::parse(&s1).expect("serialized JSON must reparse");
        assert_eq!(s1, doc2.to_string_compact(), "serialization is not a fixed point");
    }
}

/// Attacker-controlled shape dims (raw f64 bit patterns and crafted
/// overflow values) must never panic the wire decoder — `parse_shape`'s
/// checked arithmetic is the only thing between these dims and
/// `Shape::numel`'s unchecked product.
pub fn target_shape(data: &[u8]) {
    let dims: Vec<String> = data
        .chunks_exact(8)
        .map(|c| {
            let v = f64::from_bits(u64::from_le_bytes(c.try_into().unwrap()));
            format!("{v}")
        })
        .collect();
    let head = format!("{{\"variant\":\"m|fp32\",\"id\":1,\"shape\":[{}]}}", dims.join(","));
    let mut body = Vec::with_capacity(4 + head.len() + 16);
    body.extend_from_slice(&(head.len() as u32).to_le_bytes());
    body.extend_from_slice(head.as_bytes());
    // A little payload so small valid shapes exercise the length check.
    body.extend_from_slice(&[0u8; 16]);
    let _ = wire::decode_infer_request(&body);
}

// ---- artifact format targets -----------------------------------------------

/// One genuine `pdq-artifact-v1` blob (the tiny synthetic demo model),
/// packed once per process and shared by the artifact generators:
/// corruption that starts from a valid baseline penetrates far past the
/// magic/CRC outer wall, where pure byte noise dies immediately.
fn baseline_artifact() -> &'static [u8] {
    static BLOB: OnceLock<Vec<u8>> = OnceLock::new();
    BLOB.get_or_init(|| {
        let model = crate::coordinator::calibrate::demo_model("fuzz_artifact");
        pack_model(&model, PackOptions { calib_size: 4, ..PackOptions::default() })
            .expect("baseline artifact packs")
    })
}

/// The baseline artifact's manifest JSON text (header framing stripped).
fn baseline_manifest_text() -> &'static str {
    let art = baseline_artifact();
    let mlen = u32::from_le_bytes([art[6], art[7], art[8], art[9]]) as usize;
    std::str::from_utf8(&art[HEADER_LEN..HEADER_LEN + mlen]).expect("manifest is UTF-8")
}

/// Manifest JSON documents: mostly the genuine baseline manifest with one
/// structured field tampered (wrong schema, zero epoch, hostile model
/// names, emptied graph/section/variant lists, a dropped top-level key),
/// sometimes arbitrary JSON — the mutation layer adds byte damage on top.
pub fn gen_manifest_json(rng: &mut Pcg32) -> Vec<u8> {
    if rng.below(6) == 0 {
        return gen_json(rng);
    }
    let mut doc = Json::parse(baseline_manifest_text()).expect("baseline manifest parses");
    match rng.below(10) {
        // Genuine — must parse and survive the round trip untouched.
        0 | 1 => {}
        2 => {
            doc.set("schema", *rng.choice(&["pdq-artifact-v2", "", "PDQ-ARTIFACT-V1"]));
        }
        3 => {
            doc.set("epoch", 0u64);
        }
        4 => {
            doc.set("model", *rng.choice(&["", "café", "a b", "m|fp32", "m\"q"]));
        }
        5 => {
            doc.set("epoch", f64::from_bits(rng.next_u64()));
        }
        6 => {
            let mut g = Json::obj();
            g.set("nodes", Json::Arr(Vec::new())).set("outputs", Json::Arr(Vec::new()));
            doc.set("graph", g);
        }
        7 => {
            doc.set("sections", Json::Arr(Vec::new()));
        }
        8 => {
            doc.set("variants", Json::Arr(vec![Json::from("m|fp32")]));
        }
        // Drop one random top-level key: every field is required.
        _ => {
            if let Json::Obj(map) = &mut doc {
                let keys: Vec<String> = map.keys().cloned().collect();
                if !keys.is_empty() {
                    let k = rng.choice(&keys).clone();
                    map.remove(&k);
                }
            }
        }
    }
    doc.to_string_compact().into_bytes()
}

/// `Manifest::parse` must never panic on arbitrary text; any manifest it
/// accepts must `validate()` without panicking against arbitrary payload
/// lengths (typed errors are the correct response) and must re-serialize
/// to a stable fixed point — floats ride as exact bit patterns
/// (`to_bits` integers), so the round trip is bit-exact by construction.
pub fn target_manifest_json(data: &[u8]) {
    let Ok(s) = std::str::from_utf8(data) else { return };
    if let Ok(m) = Manifest::parse(s) {
        let _ = m.validate(0);
        let _ = m.validate(usize::MAX);
        let s1 = m.to_json().to_string_compact();
        let m2 = Manifest::parse(&s1).expect("re-serialized manifest must reparse");
        assert_eq!(s1, m2.to_json().to_string_compact(), "manifest JSON is not a fixed point");
    }
}

/// Whole-artifact byte blobs: the valid baseline, header-targeted
/// scribbles (magic, manifest length, manifest CRC), payload bit flips,
/// truncations, tail garbage, and magic-prefixed noise — the mutation
/// layer compounds them.
pub fn gen_artifact_payload(rng: &mut Pcg32) -> Vec<u8> {
    let mut bytes = baseline_artifact().to_vec();
    match rng.below(8) {
        // Pristine (the mutation layer may still damage it).
        0 => {}
        // Header scribble: magic, manifest length or manifest CRC.
        1 => {
            let i = rng.below(HEADER_LEN as u32) as usize;
            bytes[i] = rng.next_u32() as u8;
        }
        // Manifest-length field replaced with an arbitrary u32.
        2 => bytes[6..10].copy_from_slice(&rng.next_u32().to_le_bytes()),
        // One flipped bit somewhere in the file.
        3 | 4 => {
            let i = rng.below(bytes.len() as u32) as usize;
            bytes[i] ^= 1 << rng.below(8);
        }
        // Truncation, including mid-header and mid-manifest.
        5 => {
            let keep = rng.below(bytes.len() as u32 + 1) as usize;
            bytes.truncate(keep);
        }
        // Garbage appended past the declared payload.
        6 => bytes.extend((0..rng.below(64)).map(|_| rng.next_u32() as u8)),
        // Pure noise behind a valid magic, probing the header parser.
        _ => {
            bytes = MAGIC.to_vec();
            bytes.extend((0..rng.below(256)).map(|_| rng.next_u32() as u8));
        }
    }
    bytes
}

/// `ArtifactEngine::from_bytes` must never panic on arbitrary bytes —
/// rejecting with a typed error is the correct response to corruption.
/// Differential: anything that *does* load must also pass
/// [`inspect_bytes`] (the loader's verification is a strict superset of
/// the inspector's) and must carry a non-empty menu whose keys agree with
/// the engines behind them.
pub fn target_artifact_payload(data: &[u8]) {
    match ArtifactEngine::from_bytes(data) {
        Ok(engine) => {
            let report = inspect_bytes(data).expect("loadable artifact must pass inspection");
            assert_eq!(report.manifest.model, engine.manifest().model);
            assert_eq!(report.manifest.epoch, engine.manifest().epoch);
            assert!(!engine.menu().is_empty(), "loaded artifact with an empty menu");
            for (key, eng) in engine.menu() {
                assert_eq!(key.spec, eng.spec(), "menu key disagrees with its engine");
            }
        }
        // Typed rejection is the expected outcome for hostile bytes.
        Err(_) => {}
    }
}

// ---- SLO query + autopilot config grammars ---------------------------------

/// `/v1/slo` query strings: plausible key=value chains over the real
/// grammar's keys plus hostile spellings (case drift, duplicate keys,
/// percent-escape games, numeric extremes). The mutation layer adds raw
/// byte damage on top.
pub fn gen_slo_query(rng: &mut Pcg32) -> Vec<u8> {
    let mut parts = Vec::new();
    for _ in 0..1 + rng.below(4) {
        let key = *rng.choice(&[
            "budget_us",
            "q",
            "variant",
            "Budget_us",
            "budget_us ",
            "b%75dget_us",
            "",
        ]);
        let val = match rng.below(8) {
            0 => format!("{}", 1 + rng.next_u64() % 100_000),
            1 => "0".to_string(),
            2 => format!("{}", u64::MAX),
            3 => format!("0.{:03}", rng.below(1000)),
            4 => (*rng.choice(&["nan", "inf", "-1", "1e3", "+5", ".5", "1.0", "0.99", "1"]))
                .to_string(),
            5 => "m%7Cfp32".to_string(),
            6 => "m|int8-ours-t".to_string(),
            _ => "x".repeat(rng.below(140) as usize),
        };
        parts.push(format!("{key}={val}"));
    }
    parts.join("&").into_bytes()
}

/// `SloQuery::parse` must never panic; every accepted query must respect
/// the documented bounds and survive the canonical `render` → `parse`
/// round trip unchanged (the oracle that keeps `/v1/slo`'s strict grammar
/// honest without a reference parser).
pub fn target_slo_query(data: &[u8]) {
    use crate::obs::slo::{SloQuery, MAX_BUDGET_US};
    let Ok(s) = std::str::from_utf8(data) else { return };
    if let Ok(q) = SloQuery::parse(s) {
        if let Some(b) = q.budget_us {
            assert!((1..=MAX_BUDGET_US).contains(&b), "accepted out-of-range budget {b}");
        }
        if let Some(v) = q.q {
            assert!(v.is_finite() && v > 0.0 && v <= 1.0, "accepted bad quantile {v}");
        }
        if let Some(v) = &q.variant {
            assert!(!v.is_empty() && v.bytes().all(|b| (0x20..0x7f).contains(&b)));
        }
        let back = SloQuery::parse(&q.render()).expect("canonical render must reparse");
        assert_eq!(back, q, "query drifted through render -> parse");
    }
}

/// `--autopilot` specs with an 8-byte little-endian budget prefix, so the
/// budget bounds check fuzzes alongside the spec grammar.
pub fn gen_autopilot_spec(rng: &mut Pcg32) -> Vec<u8> {
    let budget: u64 = match rng.below(4) {
        0 => 50_000,
        1 => 0,
        2 => rng.next_u64(),
        _ => 1 + rng.next_u64() % 1_000_000,
    };
    let mut parts = Vec::new();
    for _ in 0..rng.below(5) {
        let key = *rng.choice(&[
            "depth",
            "deadline_us",
            "step",
            "exit",
            "dwell",
            "cooldown_ms",
            "tick_ms",
            "bogus",
            "",
        ]);
        let val = match rng.below(7) {
            0 => format!("{}..{}", rng.below(2000), rng.below(200_000)),
            1 => format!("{}", rng.below(1000)),
            2 => format!("0.{:02}", rng.below(100)),
            3 => (*rng.choice(&["NaN", "inf", "-1", "1e-3", "..", "4..", "..8", "0..0", "."]))
                .to_string(),
            4 => format!("{}..{}", rng.next_u64(), rng.next_u64()),
            5 => String::new(),
            _ => "9".repeat(1 + rng.below(30) as usize),
        };
        parts.push(format!("{key}={val}"));
    }
    let mut out = budget.to_le_bytes().to_vec();
    out.extend_from_slice(parts.join(",").as_bytes());
    out
}

/// `AutopilotConfig::parse` must never panic, every accepted config must
/// satisfy the control law's preconditions (ordered ranges, step/exit in
/// band — the invariants `observe` divides and clamps by), and the
/// canonical `render` must reparse to the identical config.
pub fn target_autopilot_config(data: &[u8]) {
    use crate::coordinator::autopilot::AutopilotConfig;
    let (budget, spec) = if data.len() >= 8 {
        (u64::from_le_bytes(data[..8].try_into().unwrap()), &data[8..])
    } else {
        (50_000, data)
    };
    let Ok(spec) = std::str::from_utf8(spec) else { return };
    if let Ok(cfg) = AutopilotConfig::parse(spec, budget) {
        assert!(cfg.budget_us >= 1, "zero budget must never be accepted");
        assert!(cfg.min_depth >= 1 && cfg.min_depth <= cfg.max_depth, "depth range broken");
        assert!(
            cfg.min_deadline_us >= 50 && cfg.min_deadline_us <= cfg.max_deadline_us,
            "deadline range broken"
        );
        assert!(cfg.step > 0.0 && cfg.step <= 0.5, "step out of band");
        assert!(cfg.exit_ratio > 0.0 && cfg.exit_ratio <= 0.95, "exit ratio out of band");
        assert!(cfg.dwell_ticks >= 1, "zero dwell would act on a single noisy tick");
        let back = AutopilotConfig::parse(&cfg.render(), cfg.budget_us)
            .expect("canonical render must reparse");
        assert_eq!(back, cfg, "config drifted through render -> parse");
    }
}

// ---- structure-aware int8 differential targets -----------------------------

fn rand_i8(rng: &mut Pcg32, n: usize, lo: i64, hi: i64) -> Vec<i8> {
    (0..n).map(|_| rng.int_range(lo, hi) as i8).collect()
}

fn rand_requant(rng: &mut Pcg32, channels: usize) -> Requant {
    let offset = rng.int_range(-20, 20) as i32;
    if rng.uniform() < 0.5 {
        Requant::per_tensor(2f64.powf(rng.uniform_range(-10.0, 0.0) as f64), offset)
    } else {
        let scales: Vec<f64> =
            (0..channels).map(|_| 2f64.powf(rng.uniform_range(-10.0, 0.0) as f64)).collect();
        Requant::per_channel(&scales, offset)
    }
}

/// Random small kernels through the fast int8 path vs the scalar CMSIS
/// oracles — bit-exact or panic. Weighted toward fully-connected (the
/// cheapest) so a given budget covers more cases.
pub fn diff_int8_kernels(seed: u64, iters: u32) {
    let mut rng = Pcg32::new(seed);
    for case in 0..iters {
        match rng.below(4) {
            0 => {
                let h = rng.int_range(3, 7) as usize;
                let w = rng.int_range(3, 7) as usize;
                let cin = rng.int_range(1, 4) as usize;
                let cout = rng.int_range(1, 5) as usize;
                let k = *rng.choice(&[1usize, 3]);
                let stride = *rng.choice(&[1usize, 2]);
                let pad = *rng.choice(&[0usize, k / 2]);
                let geom = ConvGeom::new(k, k, stride, pad);
                let x = Tensor::from_vec(
                    Shape::hwc(h, w, cin),
                    rand_i8(&mut rng, h * w * cin, -128, 127),
                );
                let kt = Tensor::from_vec(
                    Shape::ohwi(cout, k, k, cin),
                    rand_i8(&mut rng, cout * k * k * cin, -127, 127),
                );
                let bias: Vec<i32> =
                    (0..cout).map(|_| rng.int_range(-3000, 3000) as i32).collect();
                let off = rng.int_range(-128, 128) as i32;
                let rq = rand_requant(&mut rng, cout);
                let want = convolve_s8(&x, &kt, &bias, off, &rq, &geom);
                let mut cols = Vec::new();
                let mut got = vec![0i8; want.numel()];
                fast::convolve_s8_fast(
                    &x,
                    &kt,
                    &bias,
                    off,
                    &geom,
                    &mut cols,
                    &mut got,
                    fast::requant_epi(&rq),
                );
                assert_eq!(
                    got,
                    *want.data(),
                    "conv diverged: seed={seed:#x} case={case} h{h} w{w} cin{cin} cout{cout} k{k} s{stride} p{pad}"
                );
            }
            1 => {
                let h = rng.int_range(3, 7) as usize;
                let w = rng.int_range(3, 7) as usize;
                let c = rng.int_range(1, 5) as usize;
                let k = *rng.choice(&[1usize, 3]);
                let stride = *rng.choice(&[1usize, 2]);
                let pad = *rng.choice(&[0usize, k / 2]);
                let geom = ConvGeom::new(k, k, stride, pad);
                let x =
                    Tensor::from_vec(Shape::hwc(h, w, c), rand_i8(&mut rng, h * w * c, -128, 127));
                let kt = Tensor::from_vec(
                    Shape::new(&[c, k, k]),
                    rand_i8(&mut rng, c * k * k, -127, 127),
                );
                let bias: Vec<i32> = (0..c).map(|_| rng.int_range(-3000, 3000) as i32).collect();
                let off = rng.int_range(-128, 128) as i32;
                let rq = rand_requant(&mut rng, c);
                let want = dwconv_s8(&x, &kt, &bias, off, &rq, &geom);
                let mut wt = Vec::new();
                let mut acc_row = Vec::new();
                let mut got = vec![0i8; want.numel()];
                fast::dwconv_s8_fast(
                    &x,
                    &kt,
                    &bias,
                    off,
                    &geom,
                    &mut wt,
                    &mut acc_row,
                    &mut got,
                    fast::requant_epi(&rq),
                );
                assert_eq!(
                    got,
                    *want.data(),
                    "dwconv diverged: seed={seed:#x} case={case} h{h} w{w} c{c} k{k} s{stride} p{pad}"
                );
            }
            _ => {
                let d = rng.int_range(1, 64) as usize;
                let h = rng.int_range(1, 16) as usize;
                let x = rand_i8(&mut rng, d, -128, 127);
                let wt = Tensor::from_vec(Shape::new(&[h, d]), rand_i8(&mut rng, h * d, -127, 127));
                let bias: Vec<i32> = (0..h).map(|_| rng.int_range(-5000, 5000) as i32).collect();
                let off = rng.int_range(-128, 128) as i32;
                let rq = rand_requant(&mut rng, h);
                let want = fully_connected_s8(&x, &wt, &bias, off, &rq);
                let sums = fast::weight_row_sums(&wt);
                let mut got = vec![0i8; h];
                fast::fully_connected_s8_fast(&x, &wt, &bias, &sums, off, &mut got, fast::requant_epi(&rq));
                assert_eq!(got, want, "fc diverged: seed={seed:#x} case={case} h{h} d{d}");
            }
        }
    }
}

/// Random small *graphs* through `Int8Executor::run_q` (arena, fused fast
/// kernels) vs `run_naive` (fresh tensors, scalar kernels) — values and
/// grids bit-exact, across random modes and granularities. Each case
/// builds, calibrates and lowers a graph, so keep `iters` small.
pub fn diff_int8_graphs(seed: u64, iters: u32) {
    let mut rng = Pcg32::new(seed);
    for case in 0..iters {
        let mut g = Graph::new(Shape::hwc(6, 6, 2));
        let x = g.input();
        let cout = 1 + rng.below(3) as usize;
        let stride = 1 + rng.below(2) as usize;
        let w: Vec<f32> = (0..cout * 9 * 2).map(|_| rng.normal_ms(0.0, 0.3)).collect();
        let c = g.conv(
            x,
            Tensor::from_vec(Shape::ohwi(cout, 3, 3, 2), w),
            vec![0.01; cout],
            ConvGeom::same(3, stride),
        );
        let mut r = g.relu(c);
        if rng.below(2) == 0 {
            let wd: Vec<f32> = (0..cout * 9).map(|_| rng.normal_ms(0.05, 0.25)).collect();
            let d = g.dwconv(
                r,
                Tensor::from_vec(Shape::new(&[cout, 3, 3]), wd),
                vec![0.0; cout],
                ConvGeom::same(3, 1),
            );
            r = g.relu6(d);
        }
        let p = g.global_avg_pool(r);
        let wl: Vec<f32> = (0..3 * cout).map(|_| rng.normal_ms(0.0, 0.4)).collect();
        let l = g.linear(p, Tensor::from_vec(Shape::new(&[3, cout]), wl), vec![0.05; 3]);
        g.mark_output(l);
        let g = Arc::new(g);

        let calib: Vec<Tensor<f32>> = (0..4)
            .map(|_| {
                let data: Vec<f32> = (0..6 * 6 * 2).map(|_| rng.uniform()).collect();
                Tensor::from_vec(Shape::hwc(6, 6, 2), data)
            })
            .collect();
        let mode =
            *rng.choice(&[QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic]);
        let weight_gran = *rng.choice(&[Granularity::PerTensor, Granularity::PerChannel]);
        let gamma = *rng.choice(&[1usize, 2]);
        let mut ex = QuantExecutor::new(
            Arc::clone(&g),
            QuantSettings {
                mode,
                gamma,
                granularity: Granularity::PerTensor,
                ..Default::default()
            },
        );
        ex.calibrate(&calib);
        let int8 = Int8Executor::lower(&ex, weight_gran).expect("lowering succeeds");

        for i in 0..2 {
            let data: Vec<f32> = (0..6 * 6 * 2).map(|_| rng.uniform()).collect();
            let img = Tensor::from_vec(Shape::hwc(6, 6, 2), data);
            let naive = int8.run_naive(&img);
            let fast_out = int8.run_q(&img).expect("run_q");
            assert_eq!(naive.len(), fast_out.len());
            for (j, ((tn, qn), (tf, qf))) in naive.iter().zip(fast_out.iter()).enumerate() {
                assert_eq!(
                    qn, qf,
                    "graph diverged (grid): seed={seed:#x} case={case} {mode:?}/{weight_gran:?} γ={gamma} img{i} out{j}"
                );
                assert_eq!(
                    tn.data(),
                    tf.data(),
                    "graph diverged (values): seed={seed:#x} case={case} {mode:?}/{weight_gran:?} γ={gamma} img{i} out{j}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tiny in-module smoke: the full seeded budgets run in
    // rust/tests/fuzz_smoke.rs; these only prove the harness plumbing
    // (generate → mutate → target) is sound.
    #[test]
    fn harness_smoke() {
        run_bytes(0xF022_0001, 150, gen_http_request, target_http_request);
        run_bytes(0xF022_0002, 150, gen_wire_body, target_wire_preamble);
        run_bytes(0xF022_0003, 150, gen_variant_wire, target_variant_wire);
        run_bytes(0xF022_0004, 150, gen_json, target_json);
        run_bytes(0xF022_0005, 150, gen_shape_dims, target_shape);
        run_bytes(0xF022_0009, 150, gen_trace_header, target_trace_header);
        run_bytes(0xF022_000A, 150, gen_manifest_json, target_manifest_json);
        run_bytes(0xF022_000B, 150, gen_artifact_payload, target_artifact_payload);
        run_bytes(0xF022_000C, 150, gen_slo_query, target_slo_query);
        run_bytes(0xF022_000D, 150, gen_autopilot_spec, target_autopilot_config);
    }

    #[test]
    fn mutate_never_panics_and_changes_input() {
        let mut rng = Pcg32::new(0xF022_0006);
        let mut changed = 0;
        for _ in 0..500 {
            let mut data: Vec<u8> = (0..rng.below(32)).map(|_| rng.next_u32() as u8).collect();
            let before = data.clone();
            mutate(&mut rng, &mut data);
            if data != before {
                changed += 1;
            }
        }
        assert!(changed > 400, "mutations almost always alter the input: {changed}");
    }

    #[test]
    fn int8_differential_smoke() {
        diff_int8_kernels(0xF022_0007, 50);
        diff_int8_graphs(0xF022_0008, 1);
    }
}
