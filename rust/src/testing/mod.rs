//! Test infrastructure compiled into the library so it is reachable from
//! integration tests (`rust/tests/fuzz_smoke.rs`), the out-of-tree
//! `fuzz/` cargo-fuzz targets, and ad-hoc debugging binaries alike.
//!
//! Nothing here runs in production paths; it costs binary size only when
//! actually linked.

pub mod fuzz;
