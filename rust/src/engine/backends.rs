//! The built-in [`Engine`] implementations: fp32, fake-quant emulation,
//! and the true-int8 engine.
//!
//! Each backend pairs an immutable engine (weights, buffer plan,
//! calibration products — shared by every worker via `Arc`) with a private
//! session type that owns the backend-appropriate workspace (an
//! [`ExecArena`] for the f32 engines, an [`Int8Arena`] for int8). Because
//! only the engine can mint its session, a (executor, arena) mismatch —
//! representable and runtime-checked in the old `ExecKind`/`ArenaKind`
//! design — is now unrepresentable by construction.

use std::sync::Arc;

use super::{Engine, EngineError, KernelTrace, RunTap, Session, VariantSpec};
use crate::nn::{float_exec, ExecArena, Graph, Int8Arena, Int8Executor, MemoryPlan};
use crate::nn::{QuantExecutor, QuantMode};
use crate::tensor::{Shape, Tensor};

/// FP32 engine over the in-process float executor (the tables' FP32
/// column, served at arena speed).
pub struct FloatEngine {
    graph: Arc<Graph>,
    /// Liveness-packed buffer plan, computed once and shared by every
    /// compiled session.
    plan: Arc<MemoryPlan>,
}

impl FloatEngine {
    /// Wrap a graph for serving.
    pub fn new(graph: Arc<Graph>) -> FloatEngine {
        let plan = Arc::new(MemoryPlan::packed(&graph));
        FloatEngine { graph, plan }
    }
}

impl Engine for FloatEngine {
    fn spec(&self) -> VariantSpec {
        VariantSpec::Fp32
    }

    fn input_shape(&self) -> &Shape {
        self.graph.input_shape()
    }

    fn compile(&self) -> Result<Box<dyn Session>, EngineError> {
        Ok(Box::new(FloatSession {
            graph: Arc::clone(&self.graph),
            arena: ExecArena::new(Arc::clone(&self.plan)),
        }))
    }
}

struct FloatSession {
    graph: Arc<Graph>,
    arena: ExecArena,
}

impl Session for FloatSession {
    fn run(&mut self, input: &Tensor<f32>) -> Result<Vec<Tensor<f32>>, EngineError> {
        if input.shape() != self.graph.input_shape() {
            return Err(EngineError::ShapeMismatch {
                expected: self.graph.input_shape().clone(),
                got: input.shape().clone(),
            });
        }
        Ok(float_exec::run_with_arena(&self.graph, input, &mut self.arena))
    }

    fn input_shape(&self) -> &Shape {
        self.graph.input_shape()
    }
}

/// Fake-quant emulation engine (Fig. 1's three requantization strategies
/// on f32 carriers) over a calibrated [`QuantExecutor`].
pub struct QuantEngine {
    ex: Arc<QuantExecutor>,
}

impl QuantEngine {
    /// Wrap an executor. Calibration is checked at [`Engine::compile`]
    /// time, not here, so a still-to-be-calibrated executor can be staged.
    pub fn new(ex: Arc<QuantExecutor>) -> QuantEngine {
        QuantEngine { ex }
    }

    /// The underlying executor (ablation drivers, oracles).
    pub fn executor(&self) -> &Arc<QuantExecutor> {
        &self.ex
    }
}

impl Engine for QuantEngine {
    fn spec(&self) -> VariantSpec {
        let s = self.ex.settings();
        VariantSpec::FakeQuant { mode: s.mode, gran: s.granularity }
    }

    fn input_shape(&self) -> &Shape {
        self.ex.graph().input_shape()
    }

    fn compile(&self) -> Result<Box<dyn Session>, EngineError> {
        // Static needs the frozen ranges, PDQ the fitted (α, β); only
        // dynamic mode is calibration-free (§3).
        if self.ex.settings().mode != QuantMode::Dynamic && !self.ex.is_calibrated() {
            return Err(EngineError::NotCalibrated(format!(
                "{} variant compiled before calibrate()",
                self.ex.settings().mode.label()
            )));
        }
        Ok(Box::new(QuantSession { arena: self.ex.make_arena(), ex: Arc::clone(&self.ex) }))
    }
}

struct QuantSession {
    ex: Arc<QuantExecutor>,
    arena: ExecArena,
}

impl Session for QuantSession {
    fn run(&mut self, input: &Tensor<f32>) -> Result<Vec<Tensor<f32>>, EngineError> {
        self.ex.run_with_arena(input, &mut self.arena)
    }

    fn input_shape(&self) -> &Shape {
        self.ex.graph().input_shape()
    }
}

/// True-int8 engine over a lowered [`Int8Executor`]; responses are
/// dequantized to f32 at the session boundary (drop-in for the f32
/// engines, bit-exact vs the scalar CMSIS oracle).
pub struct Int8Engine {
    ex: Arc<Int8Executor>,
}

impl Int8Engine {
    /// Wrap a lowered program (lowering already guarantees calibration).
    pub fn new(ex: Arc<Int8Executor>) -> Int8Engine {
        Int8Engine { ex }
    }

    /// The underlying lowered program (oracles, benchmarks).
    pub fn executor(&self) -> &Arc<Int8Executor> {
        &self.ex
    }
}

impl Engine for Int8Engine {
    fn spec(&self) -> VariantSpec {
        VariantSpec::Int8 {
            mode: self.ex.mode(),
            weight_gran: self.ex.weight_granularity(),
            bits: self.ex.bits(),
        }
    }

    fn input_shape(&self) -> &Shape {
        self.ex.input_shape()
    }

    fn compile(&self) -> Result<Box<dyn Session>, EngineError> {
        Ok(Box::new(Int8Session { arena: self.ex.make_arena(), ex: Arc::clone(&self.ex) }))
    }
}

struct Int8Session {
    ex: Arc<Int8Executor>,
    arena: Int8Arena,
}

impl Session for Int8Session {
    fn run(&mut self, input: &Tensor<f32>) -> Result<Vec<Tensor<f32>>, EngineError> {
        self.ex.run_with_arena(input, &mut self.arena)
    }

    /// The deep integer tap: per-layer γ-strided window statistics plus
    /// output clip counters, collected inside the same forward pass (the
    /// kernels are untouched, so outputs stay bit-identical to `run`).
    fn run_tapped(
        &mut self,
        input: &Tensor<f32>,
        tap: &mut RunTap,
    ) -> Result<Vec<Tensor<f32>>, EngineError> {
        self.ex.run_tapped_with_arena(input, &mut self.arena, tap)
    }

    /// The deep timing trace: one kernel span per lowered node plus the
    /// dequantize tail, collected around the same `eval_node` calls the
    /// untraced path makes (outputs stay bit-identical to `run`).
    fn run_traced(
        &mut self,
        input: &Tensor<f32>,
        ktrace: &mut KernelTrace,
    ) -> Result<Vec<Tensor<f32>>, EngineError> {
        self.ex.run_traced_with_arena(input, &mut self.arena, ktrace)
    }

    fn input_shape(&self) -> &Shape {
        self.ex.input_shape()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quant_exec::QuantSettings;
    use crate::tensor::{ConvGeom, Shape};
    use crate::util::Pcg32;

    fn tiny_graph() -> Arc<Graph> {
        let mut rng = Pcg32::new(0xE6E6);
        let mut g = Graph::new(Shape::hwc(6, 6, 2));
        let x = g.input();
        let w: Vec<f32> = (0..4 * 9 * 2).map(|_| rng.normal_ms(0.0, 0.3)).collect();
        let c = g.conv(
            x,
            Tensor::from_vec(Shape::ohwi(4, 3, 3, 2), w),
            vec![0.0; 4],
            ConvGeom::same(3, 1),
        );
        let r = g.relu(c);
        let p = g.global_avg_pool(r);
        g.mark_output(p);
        Arc::new(g)
    }

    fn image(seed: u64) -> Tensor<f32> {
        let mut rng = Pcg32::new(seed);
        let d: Vec<f32> = (0..6 * 6 * 2).map(|_| rng.uniform()).collect();
        Tensor::from_vec(Shape::hwc(6, 6, 2), d)
    }

    #[test]
    fn float_engine_matches_arena_executor_bit_exactly() {
        let g = tiny_graph();
        let engine = FloatEngine::new(Arc::clone(&g));
        assert_eq!(engine.spec(), VariantSpec::Fp32);
        let mut session = engine.compile().unwrap();
        let img = image(1);
        let got = session.run(&img).unwrap();
        // Compare against the exact path the session wraps (the arena
        // engine); the naive-oracle parity bound lives in kernel_parity.
        let mut arena = crate::nn::ExecArena::for_run(&g);
        let want = float_exec::run_with_arena(&g, &img, &mut arena);
        assert_eq!(got[0].data(), want[0].data());
    }

    #[test]
    fn sessions_reject_bad_shapes_with_typed_error() {
        let engine = FloatEngine::new(tiny_graph());
        let mut session = engine.compile().unwrap();
        let bad = Tensor::full(Shape::hwc(2, 2, 1), 0.0);
        match session.run(&bad) {
            Err(EngineError::ShapeMismatch { expected, got }) => {
                assert_eq!(expected.dims(), &[6, 6, 2]);
                assert_eq!(got.dims(), &[2, 2, 1]);
            }
            other => panic!("want ShapeMismatch, got {:?}", other.err()),
        }
    }

    #[test]
    fn uncalibrated_static_compile_is_typed_error() {
        let ex = QuantExecutor::new(
            tiny_graph(),
            QuantSettings { mode: QuantMode::Static, ..Default::default() },
        );
        let engine = QuantEngine::new(Arc::new(ex));
        assert!(matches!(engine.compile(), Err(EngineError::NotCalibrated(_))));
        // Dynamic mode is calibration-free and must compile.
        let exd = QuantExecutor::new(
            tiny_graph(),
            QuantSettings { mode: QuantMode::Dynamic, ..Default::default() },
        );
        assert!(QuantEngine::new(Arc::new(exd)).compile().is_ok());
    }

    #[test]
    fn run_traced_is_bit_identical_and_times_nodes() {
        // Int8 backend: per-node kernel spans, outputs bit-exact vs run().
        let g = tiny_graph();
        let mut ex = QuantExecutor::new(
            Arc::clone(&g),
            QuantSettings { mode: QuantMode::Probabilistic, ..Default::default() },
        );
        ex.calibrate(&[image(7), image(8)]);
        let int8 =
            Int8Executor::lower(&ex, crate::quant::Granularity::PerChannel).unwrap();
        let engine = Int8Engine::new(Arc::new(int8));
        let mut session = engine.compile().unwrap();
        let img = image(9);
        let want: Vec<u32> = session.run(&img).unwrap()[0].data().iter().map(|x| x.to_bits()).collect();
        let mut kt = KernelTrace::new();
        let got: Vec<u32> =
            session.run_traced(&img, &mut kt).unwrap()[0].data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(want, got, "traced run must not perturb outputs");
        assert_eq!(kt.spans.len(), 4, "one span per lowered node");
        assert_eq!(kt.spans[0].op, "input");
        assert!(kt.spans.iter().all(|s| s.us >= 0.0));

        // Default (float) backend: contract holds, buffer stays empty.
        let fe = FloatEngine::new(g);
        let mut fs = fe.compile().unwrap();
        let want: Vec<u32> = fs.run(&img).unwrap()[0].data().iter().map(|x| x.to_bits()).collect();
        kt.push(0, "stale", 1.0);
        let got: Vec<u32> =
            fs.run_traced(&img, &mut kt).unwrap()[0].data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(want, got);
        assert!(kt.spans.is_empty(), "default impl clears the buffer");
    }

    #[test]
    fn run_batch_defaults_to_per_item_runs() {
        let engine = FloatEngine::new(tiny_graph());
        let mut session = engine.compile().unwrap();
        let imgs = [image(1), image(2), image(3)];
        let batch = session.run_batch(&imgs).unwrap();
        assert_eq!(batch.len(), 3);
        for (img, out) in imgs.iter().zip(&batch) {
            assert_eq!(out[0].data(), session.run(img).unwrap()[0].data());
        }
    }
}
