//! [`SessionPool`]: per-worker session reuse, epoch-aware.
//!
//! Sessions own mutable workspaces (arenas, estimator scratch), so they
//! cannot be shared — but compiling one per request would re-allocate the
//! very buffers the arena design exists to amortize. The pool checks
//! sessions out RAII-style: [`SessionPool::acquire`] pops an idle session
//! (or compiles one lazily, so a pool serving `n` concurrent workers
//! never holds more than `n` sessions), and dropping the
//! [`PooledSession`] returns it warm for the next batch.
//!
//! The pool draws its engine from an [`EngineCell`], so a live
//! recalibration ([`crate::adapt`]) is honored at checkout: `acquire`
//! reads the cell's current `(epoch, engine)` pair, drops any pooled
//! session compiled under an older epoch, and compiles fresh sessions
//! from the newly published engine — while sessions already checked out
//! keep executing on the old engine's grids until they are returned. A
//! pool built with [`SessionPool::new`] wraps a private cell that never
//! publishes, which is the zero-overhead static-serving path.

use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Mutex};

use super::{Engine, EngineCell, EngineError, Session};

/// A pool of reusable [`Session`]s for one engine cell.
pub struct SessionPool {
    cell: Arc<EngineCell>,
    free: Mutex<Vec<(u64, Box<dyn Session>)>>,
}

impl SessionPool {
    /// Create an empty pool over a fixed `engine` (sessions are compiled
    /// lazily; the engine never changes — the pre-adaptation behavior).
    pub fn new(engine: Arc<dyn Engine>) -> SessionPool {
        SessionPool::over(Arc::new(EngineCell::new(engine)))
    }

    /// Create an empty pool over a shared [`EngineCell`] whose engine may
    /// be swapped by a recalibration worker.
    pub fn over(cell: Arc<EngineCell>) -> SessionPool {
        SessionPool { cell, free: Mutex::new(Vec::new()) }
    }

    /// The currently published engine.
    pub fn engine(&self) -> Arc<dyn Engine> {
        self.cell.current().1
    }

    /// The cell the pool draws from.
    pub fn cell(&self) -> &Arc<EngineCell> {
        &self.cell
    }

    /// The epoch the next checkout will serve under.
    pub fn epoch(&self) -> u64 {
        self.cell.epoch()
    }

    /// Check a session out, compiling a fresh one only when every pooled
    /// session of the *current epoch* is in use. Sessions pooled under an
    /// older epoch are discarded here — this is the swap point where new
    /// checkouts start seeing freshly recalibrated grids.
    pub fn acquire(&self) -> Result<PooledSession<'_>, EngineError> {
        let (epoch, engine) = self.cell.current();
        let cached = {
            let mut free = self.free.lock().unwrap();
            free.retain(|(e, _)| *e == epoch);
            free.pop()
        };
        let session = match cached {
            Some((_, s)) => s,
            None => engine.compile()?,
        };
        Ok(PooledSession { pool: self, epoch, session: Some(session) })
    }

    /// How many sessions are currently idle in the pool (any epoch).
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// A checked-out session; derefs to [`Session`] and returns itself to the
/// pool on drop.
pub struct PooledSession<'p> {
    pool: &'p SessionPool,
    epoch: u64,
    session: Option<Box<dyn Session>>,
}

impl PooledSession<'_> {
    /// Which engine epoch this session was compiled under.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

impl Deref for PooledSession<'_> {
    type Target = dyn Session;

    fn deref(&self) -> &Self::Target {
        self.session.as_deref().expect("session present until drop")
    }
}

impl DerefMut for PooledSession<'_> {
    fn deref_mut(&mut self) -> &mut Self::Target {
        self.session.as_deref_mut().expect("session present until drop")
    }
}

impl Drop for PooledSession<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.session.take() {
            // Stale returns are tolerated here and swept at the next
            // acquire, so drop stays cheap and lock-ordering trivial.
            self.pool.free.lock().unwrap().push((self.epoch, s));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FloatEngine;
    use crate::nn::Graph;
    use crate::tensor::{Shape, Tensor};
    use std::sync::Arc;

    fn relu_engine(shape: Shape) -> Arc<dyn Engine> {
        let mut g = Graph::new(shape);
        let x = g.input();
        let r = g.relu(x);
        g.mark_output(r);
        Arc::new(FloatEngine::new(Arc::new(g)))
    }

    fn pool() -> SessionPool {
        SessionPool::new(relu_engine(Shape::hwc(2, 2, 1)))
    }

    #[test]
    fn sessions_are_reused_not_multiplied() {
        let pool = pool();
        assert_eq!(pool.idle(), 0);
        let img = Tensor::full(Shape::hwc(2, 2, 1), 1.0);
        for _ in 0..5 {
            let mut s = pool.acquire().unwrap();
            let out = s.run(&img).unwrap();
            assert_eq!(out[0].data(), &[1.0; 4]);
        }
        // Sequential checkouts reuse the single compiled session.
        assert_eq!(pool.idle(), 1);
        // Two concurrent checkouts force a second compile.
        let a = pool.acquire().unwrap();
        let b = pool.acquire().unwrap();
        drop(a);
        drop(b);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        let pool = Arc::new(pool());
        let mut joins = Vec::new();
        for t in 0..4u32 {
            let pool = Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                let img = Tensor::full(Shape::hwc(2, 2, 1), t as f32);
                for _ in 0..8 {
                    let mut s = pool.acquire().unwrap();
                    let out = s.run(&img).unwrap();
                    assert_eq!(out[0].data()[0], t as f32);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert!(pool.idle() >= 1 && pool.idle() <= 4);
    }

    /// The epoch-swap contract: an in-flight checkout finishes on the old
    /// engine; the next checkout compiles from the published one; stale
    /// pooled sessions are discarded, not reused.
    #[test]
    fn checkout_honors_the_epoch() {
        let cell = Arc::new(EngineCell::new(relu_engine(Shape::hwc(2, 2, 1))));
        let pool = SessionPool::over(Arc::clone(&cell));
        let img = Tensor::full(Shape::hwc(2, 2, 1), 2.0);

        // Warm one session under epoch 0 and keep it checked out.
        let mut held = pool.acquire().unwrap();
        assert_eq!(held.epoch(), 0);
        // Pool another epoch-0 session.
        drop(pool.acquire().unwrap());
        assert_eq!(pool.idle(), 1);

        cell.publish(relu_engine(Shape::hwc(2, 2, 1)));

        // The held (in-flight) session still runs — old grids finish out.
        assert_eq!(held.run(&img).unwrap()[0].data(), &[2.0; 4]);
        drop(held);
        assert_eq!(pool.idle(), 2, "stale sessions returned, not yet swept");

        // New checkout: stale sessions swept, fresh session at epoch 1.
        let s = pool.acquire().unwrap();
        assert_eq!(s.epoch(), 1);
        assert_eq!(pool.epoch(), 1);
        drop(s);
        assert_eq!(pool.idle(), 1, "only the epoch-1 session remains pooled");
    }
}
