//! [`SessionPool`]: per-worker session reuse.
//!
//! Sessions own mutable workspaces (arenas, estimator scratch), so they
//! cannot be shared — but compiling one per request would re-allocate the
//! very buffers the arena design exists to amortize. The pool checks
//! sessions out RAII-style: [`SessionPool::acquire`] pops an idle session
//! (or compiles one lazily, so a pool serving `n` concurrent workers
//! never holds more than `n` sessions), and dropping the
//! [`PooledSession`] returns it warm for the next batch.

use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Mutex};

use super::{Engine, EngineError, Session};

/// A pool of reusable [`Session`]s for one engine.
pub struct SessionPool {
    engine: Arc<dyn Engine>,
    free: Mutex<Vec<Box<dyn Session>>>,
}

impl SessionPool {
    /// Create an empty pool over `engine` (sessions are compiled lazily).
    pub fn new(engine: Arc<dyn Engine>) -> SessionPool {
        SessionPool { engine, free: Mutex::new(Vec::new()) }
    }

    /// The pooled engine.
    pub fn engine(&self) -> &Arc<dyn Engine> {
        &self.engine
    }

    /// Check a session out, compiling a fresh one only when every pooled
    /// session is in use.
    pub fn acquire(&self) -> Result<PooledSession<'_>, EngineError> {
        let cached = self.free.lock().unwrap().pop();
        let session = match cached {
            Some(s) => s,
            None => self.engine.compile()?,
        };
        Ok(PooledSession { pool: self, session: Some(session) })
    }

    /// How many sessions are currently idle in the pool.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// A checked-out session; derefs to [`Session`] and returns itself to the
/// pool on drop.
pub struct PooledSession<'p> {
    pool: &'p SessionPool,
    session: Option<Box<dyn Session>>,
}

impl Deref for PooledSession<'_> {
    type Target = dyn Session;

    fn deref(&self) -> &Self::Target {
        self.session.as_deref().expect("session present until drop")
    }
}

impl DerefMut for PooledSession<'_> {
    fn deref_mut(&mut self) -> &mut Self::Target {
        self.session.as_deref_mut().expect("session present until drop")
    }
}

impl Drop for PooledSession<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.session.take() {
            self.pool.free.lock().unwrap().push(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FloatEngine;
    use crate::nn::Graph;
    use crate::tensor::{Shape, Tensor};
    use std::sync::Arc;

    fn pool() -> SessionPool {
        let mut g = Graph::new(Shape::hwc(2, 2, 1));
        let x = g.input();
        let r = g.relu(x);
        g.mark_output(r);
        SessionPool::new(Arc::new(FloatEngine::new(Arc::new(g))))
    }

    #[test]
    fn sessions_are_reused_not_multiplied() {
        let pool = pool();
        assert_eq!(pool.idle(), 0);
        let img = Tensor::full(Shape::hwc(2, 2, 1), 1.0);
        for _ in 0..5 {
            let mut s = pool.acquire().unwrap();
            let out = s.run(&img).unwrap();
            assert_eq!(out[0].data(), &[1.0; 4]);
        }
        // Sequential checkouts reuse the single compiled session.
        assert_eq!(pool.idle(), 1);
        // Two concurrent checkouts force a second compile.
        let a = pool.acquire().unwrap();
        let b = pool.acquire().unwrap();
        drop(a);
        drop(b);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        let pool = Arc::new(pool());
        let mut joins = Vec::new();
        for t in 0..4u32 {
            let pool = Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                let img = Tensor::full(Shape::hwc(2, 2, 1), t as f32);
                for _ in 0..8 {
                    let mut s = pool.acquire().unwrap();
                    let out = s.run(&img).unwrap();
                    assert_eq!(out[0].data()[0], t as f32);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert!(pool.idle() >= 1 && pool.idle() <= 4);
    }
}
