//! The per-run observation tap: what a [`super::Session`] reports about one
//! request when observation is enabled.
//!
//! The tap is the engine-side half of the online-adaptation loop
//! ([`crate::adapt`] owns the accumulation, drift scoring, and
//! recalibration). A session fills a [`RunTap`] with *integer* statistics —
//! the same mergeable `S1 = Σ(q − z)` / `S2 = Σ(q − z)²` window accumulators
//! the paper's §4.2 estimator streams ([`WindowStats`]) — plus a clip
//! counter per tapped node (how many produced values sit on the grid's
//! extreme codes, i.e. the γ-coverage knob made observable). Integer
//! accumulation keeps the hot-path cost of a tapped run at one extra
//! strided pass per layer, and sampled observation amortizes even that to
//! near zero.

use crate::estimator::fixed::WindowStats;
use crate::quant::QParams;
use crate::tensor::Tensor;

/// One tapped node's statistics for a single run.
#[derive(Clone, Copy, Debug)]
pub struct NodeTap {
    /// Graph node id the statistics belong to (node 0 is the input).
    pub node: usize,
    /// Scale of the int8 grid the integer sums were accumulated on —
    /// needed to convert the sums to real units at snapshot time (grids
    /// may change across recalibration epochs; real units stay comparable).
    pub scale: f32,
    /// γ-strided window accumulators of the node's *input* (`S1`/`S2`
    /// sums — the paper's constant-memory estimation state).
    pub window: WindowStats,
    /// Output values observed at the grid's extreme codes (saturation).
    pub clipped: u64,
    /// Total output values inspected for the clip counter.
    pub total: u64,
}

/// A per-request collection buffer for node taps, reused across runs.
#[derive(Clone, Debug)]
pub struct RunTap {
    /// Sampling stride γ for the window statistics of conv-like nodes
    /// (tapping uses its own stride so observation can be cheaper than the
    /// serving estimator's γ).
    pub gamma: usize,
    /// The taps collected during the current run.
    pub nodes: Vec<NodeTap>,
}

impl RunTap {
    /// An empty tap with the given observation stride (`gamma >= 1`).
    pub fn new(gamma: usize) -> RunTap {
        assert!(gamma >= 1, "tap gamma must be >= 1");
        RunTap { gamma, nodes: Vec::new() }
    }

    /// Drop the previous run's entries (capacity is retained).
    pub fn clear(&mut self) {
        self.nodes.clear();
    }

    /// Record one node's statistics.
    pub fn push(&mut self, node: usize, scale: f32, window: WindowStats, clipped: u64, total: u64) {
        self.nodes.push(NodeTap { node, scale, window, clipped, total });
    }

    /// The fallback boundary tap every backend supports: quantize the f32
    /// input onto the executor's fixed `[0, 1]` image grid (the same grid
    /// the int8 engine's input node uses) and record its integer sums plus
    /// the fraction of pixels on the grid extremes, as node 0. Backends
    /// with deeper integer taps (the int8 engine) record per-layer entries
    /// instead.
    pub fn observe_input_grid(&mut self, input: &Tensor<f32>) {
        let qp = QParams::from_range(0.0, 1.0, 8);
        let zero = qp.zero_point;
        let mut s1 = 0i64;
        let mut s2 = 0i64;
        let mut clipped = 0u64;
        for &v in input.data() {
            let q = ((v / qp.scale).round() as i32 + zero).clamp(-128, 127);
            if q == -128 || q == 127 {
                clipped += 1;
            }
            let d = (q - zero) as i64;
            s1 += d;
            s2 += d * d;
        }
        let mut st = WindowStats::default();
        st.push(s1, s2);
        self.push(0, qp.scale, st, clipped, input.numel() as u64);
    }
}

/// One node's wall-clock kernel timing for a single traced run.
///
/// The timing sibling of [`NodeTap`]: where the adaptation tap reports
/// *statistics* (integer sums, clip counts), the kernel span reports
/// *where the microseconds went* — one entry per lowered node, in
/// execution order.
#[derive(Clone, Copy, Debug)]
pub struct KernelSpan {
    /// Graph node id the span belongs to (node 0 is the input).
    pub node: usize,
    /// Short operator name (`conv`, `dwconv`, `linear`, `relu`, ...).
    pub op: &'static str,
    /// Wall-clock duration of the node's kernel, in microseconds.
    pub us: f64,
}

/// A per-request collection buffer for kernel spans, reused across runs.
///
/// Mirrors [`RunTap`]'s arming discipline: the serving path only
/// constructs one when request tracing is armed, so the disarmed hot
/// path carries no cost at all, and a traced run evaluates nodes through
/// the exact same kernels as an untraced one — outputs are bit-identical
/// by construction (the adaptation invariant, extended to timing).
#[derive(Clone, Debug, Default)]
pub struct KernelTrace {
    /// Per-node kernel spans in execution order.
    pub spans: Vec<KernelSpan>,
    /// Microseconds spent requantizing/dequantizing outputs back to f32
    /// after the last node (0 for backends with no requantize step).
    pub requant_us: f64,
}

impl KernelTrace {
    /// An empty kernel trace.
    pub fn new() -> KernelTrace {
        KernelTrace::default()
    }

    /// Drop the previous run's entries (capacity is retained).
    pub fn clear(&mut self) {
        self.spans.clear();
        self.requant_us = 0.0;
    }

    /// Record one node's kernel timing.
    pub fn push(&mut self, node: usize, op: &'static str, us: f64) {
        self.spans.push(KernelSpan { node, op, us });
    }

    /// Total microseconds across all recorded kernel spans (excluding
    /// the requantize tail).
    pub fn kernel_us(&self) -> f64 {
        self.spans.iter().map(|s| s.us).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    #[test]
    fn kernel_trace_accumulates_and_clears() {
        let mut kt = KernelTrace::new();
        kt.push(0, "input", 1.5);
        kt.push(1, "conv", 20.0);
        kt.requant_us = 3.0;
        assert_eq!(kt.spans.len(), 2);
        assert!((kt.kernel_us() - 21.5).abs() < 1e-9);
        kt.clear();
        assert!(kt.spans.is_empty());
        assert_eq!(kt.requant_us, 0.0);
    }

    #[test]
    fn boundary_tap_records_node_zero() {
        let img = Tensor::from_vec(Shape::hwc(2, 2, 1), vec![0.0, 0.5, 1.0, 0.25]);
        let mut tap = RunTap::new(1);
        tap.observe_input_grid(&img);
        assert_eq!(tap.nodes.len(), 1);
        let nt = &tap.nodes[0];
        assert_eq!(nt.node, 0);
        assert_eq!(nt.total, 4);
        // 0.0 and 1.0 sit on the grid extremes of the [0, 1] image grid.
        assert_eq!(nt.clipped, 2);
        assert_eq!(nt.window.n, 1);
        // Mean in real units recovers the pixel mean to within a grid step.
        let mean = nt.scale as f64 * nt.window.sum_s1 as f64 / 4.0;
        assert!((mean - 0.4375).abs() < 2.0 * nt.scale as f64, "{mean}");
    }

    #[test]
    fn clear_retains_gamma() {
        let img = Tensor::full(Shape::hwc(2, 2, 1), 0.5);
        let mut tap = RunTap::new(3);
        tap.observe_input_grid(&img);
        tap.clear();
        assert!(tap.nodes.is_empty());
        assert_eq!(tap.gamma, 3);
    }
}
