//! The typed error surface of the [`crate::engine`] API.
//!
//! Every failure a caller can provoke — bad input shapes, running an
//! uncalibrated variant, asking for an unbuildable configuration — is a
//! variant here instead of a `panic!` inside an executor. The serving
//! boundary maps these onto HTTP statuses (`ShapeMismatch` → 400, the
//! rest → 500), so a worker thread can never be killed by request data.

use crate::tensor::Shape;

/// Why an engine could not be built, compiled, or run.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineError {
    /// The input tensor's shape does not match the compiled program's
    /// input shape.
    ShapeMismatch {
        /// The shape the compiled program expects.
        expected: Shape,
        /// The shape the caller provided.
        got: Shape,
    },
    /// The variant requires calibration products (frozen ranges, fitted
    /// `(α, β)` intervals) that were never produced.
    NotCalibrated(String),
    /// The requested (mode, granularity, bits, γ) combination is not
    /// representable on the chosen backend.
    InvalidSpec(String),
    /// The backend failed internally.
    Backend(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::ShapeMismatch { expected, got } => {
                write!(f, "input shape mismatch: got {got}, variant expects {expected}")
            }
            EngineError::NotCalibrated(what) => write!(f, "not calibrated: {what}"),
            EngineError::InvalidSpec(why) => write!(f, "invalid variant spec: {why}"),
            EngineError::Backend(why) => write!(f, "backend error: {why}"),
        }
    }
}

impl std::error::Error for EngineError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_both_shapes() {
        let e = EngineError::ShapeMismatch {
            expected: Shape::hwc(8, 8, 2),
            got: Shape::hwc(2, 2, 1),
        };
        let msg = e.to_string();
        assert!(msg.contains("[8, 8, 2]") && msg.contains("[2, 2, 1]"), "{msg}");
    }
}
