//! # `pdq::engine` — the crate's front-door execution API.
//!
//! The paper's pitch is that PDQ is a *drop-in requantization policy*: the
//! same network, three parameter-selection strategies (§3, Fig. 1). This
//! module makes that the shape of the API. One [`Engine`] abstraction
//! serves every backend — fp32, fake-quant emulation, true int8, and
//! whatever comes next (a PJRT runtime, other bit widths) — so callers
//! never touch backend-specific executors, arenas, or the parallel enums
//! that used to glue them together.
//!
//! ```text
//!  EngineBuilder ──build()──▶ Arc<dyn Engine> ──compile()──▶ Box<dyn Session>
//!   model + VariantSpec        immutable, shared             owns its arena,
//!   + γ/bits/coverage          across workers                one per worker
//!   + calibration set
//! ```
//!
//! - [`VariantSpec`] / [`VariantKey`] — variant identity and the stable
//!   `<model>|<mode>` wire naming.
//! - [`EngineBuilder`] — the one construction path (model + spec + knobs +
//!   calibration), plus [`standard_menu`] for the full serving menu.
//! - [`Engine`] / [`Session`] — compile-then-run; a session owns its
//!   backend-appropriate workspace, so executor/arena mismatches are
//!   unrepresentable.
//! - [`SessionPool`] — RAII per-worker session reuse.
//! - [`EngineError`] — typed shape/calibration/spec/backend errors; no
//!   panic is reachable from request data.
//!
//! ## Quickstart
//!
//! ```no_run
//! use pdq::engine::{EngineBuilder, VariantSpec};
//! use pdq::nn::QuantMode;
//! use pdq::quant::Granularity;
//! # fn main() -> Result<(), Box<dyn std::error::Error>> {
//! # let model = pdq::coordinator::calibrate::demo_model("demo");
//! # let image = pdq::engine::calibration_images(model.task, 1).remove(0);
//! let engine = EngineBuilder::new(&model)
//!     .spec(VariantSpec::FakeQuant {
//!         mode: QuantMode::Probabilistic,
//!         gran: Granularity::PerTensor,
//!     })
//!     .gamma(2)
//!     .build()?;
//! let mut session = engine.compile()?;
//! let outputs = session.run(&image)?;
//! # let _ = outputs;
//! # Ok(())
//! # }
//! ```

#![deny(missing_docs)]
#![deny(clippy::all)]

mod backends;
mod builder;
mod cell;
mod error;
mod pool;
mod spec;
mod tap;

pub use backends::{FloatEngine, Int8Engine, QuantEngine};
pub use builder::{calibration_images, standard_menu, EngineBuilder, CALIB_SIZE};
pub use cell::EngineCell;
pub use error::EngineError;
pub use pool::{PooledSession, SessionPool};
pub use spec::{VariantKey, VariantSpec};
pub use tap::{KernelSpan, KernelTrace, NodeTap, RunTap};

use crate::tensor::{Shape, Tensor};

/// A compiled, servable model variant.
///
/// An engine is the immutable half of a backend — weights, buffer plans,
/// calibration products — shared across worker threads behind an `Arc`.
/// [`Engine::compile`] mints [`Session`]s that own the mutable per-worker
/// state (arenas, scratch). New backends implement this trait instead of
/// growing match arms in the coordinator.
pub trait Engine: Send + Sync {
    /// Which variant this engine executes.
    fn spec(&self) -> VariantSpec;

    /// The input shape every session of this engine expects.
    fn input_shape(&self) -> &Shape;

    /// Create a session owning its backend-appropriate workspace.
    ///
    /// Fails with [`EngineError::NotCalibrated`] when the variant's
    /// calibration products are missing (e.g. a static-mode executor that
    /// never saw `calibrate()`), so the failure surfaces where the session
    /// is minted — at pool checkout in the serving path — as one typed
    /// error per batch, never as a panic deep inside a request's kernels.
    fn compile(&self) -> Result<Box<dyn Session>, EngineError>;
}

/// A per-worker execution context: exclusive, reusable, allocation-free in
/// steady state.
pub trait Session: Send {
    /// Run one input; returns the model's output tensors (f32 at the API
    /// boundary for every backend — int8 engines dequantize on the way
    /// out, keeping sessions drop-in interchangeable).
    fn run(&mut self, input: &Tensor<f32>) -> Result<Vec<Tensor<f32>>, EngineError>;

    /// Run a batch; the default executes [`Session::run`] per item on this
    /// session's workspace. Backends with true batch kernels override it.
    fn run_batch(&mut self, inputs: &[Tensor<f32>]) -> Result<Vec<Vec<Tensor<f32>>>, EngineError> {
        inputs.iter().map(|input| self.run(input)).collect()
    }

    /// The opt-in observation hook: run one input while filling `tap` with
    /// this run's statistics ([`crate::adapt`] drives it on sampled
    /// requests). The outputs MUST be bit-identical to [`Session::run`] on
    /// the same input — observation reads, it never perturbs.
    ///
    /// The default implementation runs normally and records only the
    /// session-boundary statistics ([`RunTap::observe_input_grid`]);
    /// backends with deeper integer taps (the int8 engine) override it with
    /// per-layer window statistics and clip counters.
    fn run_tapped(
        &mut self,
        input: &Tensor<f32>,
        tap: &mut RunTap,
    ) -> Result<Vec<Tensor<f32>>, EngineError> {
        tap.clear();
        let outputs = self.run(input)?;
        tap.observe_input_grid(input);
        Ok(outputs)
    }

    /// The opt-in *timing* hook: run one input while filling `ktrace` with
    /// per-node kernel spans (the flight recorder drives it on traced
    /// requests). Like [`Session::run_tapped`], the outputs MUST be
    /// bit-identical to [`Session::run`] on the same input — tracing
    /// observes the clock, it never perturbs the arithmetic.
    ///
    /// The default implementation runs normally and records nothing beyond
    /// clearing the buffer — backends without per-node visibility still
    /// satisfy the contract. The int8 engine overrides it to time each
    /// lowered node plus the output requantize tail.
    fn run_traced(
        &mut self,
        input: &Tensor<f32>,
        ktrace: &mut KernelTrace,
    ) -> Result<Vec<Tensor<f32>>, EngineError> {
        ktrace.clear();
        self.run(input)
    }

    /// The input shape this session expects.
    fn input_shape(&self) -> &Shape;
}
