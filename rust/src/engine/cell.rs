//! [`EngineCell`]: the atomic epoch swap behind zero-downtime
//! recalibration.
//!
//! A cell holds the *current* engine of a served variant behind an
//! `RwLock<Arc<dyn Engine>>` plus a monotonically increasing generation
//! counter. Publishing a replacement engine (a shadow-recalibrated build,
//! [`crate::adapt::recalib`]) swaps the `Arc` and bumps the epoch in one
//! critical section, so readers always observe a consistent
//! `(epoch, engine)` pair:
//!
//! - **in-flight batches finish on the old grids** — a compiled session
//!   keeps its own `Arc`s into the old engine's weights and requant specs,
//!   so nothing it reads can change mid-request;
//! - **new checkouts see the new grids** — [`super::SessionPool::acquire`]
//!   reads the cell first and discards pooled sessions whose epoch is
//!   stale, compiling from the freshly published engine instead.
//!
//! The swap preserves the variant's identity: publishing an engine with a
//! different [`super::VariantSpec`] is a registration bug and panics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use super::Engine;

/// The swappable engine slot of one served variant (see module docs).
pub struct EngineCell {
    engine: RwLock<Arc<dyn Engine>>,
    epoch: AtomicU64,
}

impl EngineCell {
    /// Wrap an engine as epoch 0.
    pub fn new(engine: Arc<dyn Engine>) -> EngineCell {
        EngineCell { engine: RwLock::new(engine), epoch: AtomicU64::new(0) }
    }

    /// The current `(epoch, engine)` pair, read consistently.
    pub fn current(&self) -> (u64, Arc<dyn Engine>) {
        let guard = self.engine.read().unwrap();
        (self.epoch.load(Ordering::Acquire), Arc::clone(&guard))
    }

    /// The current generation counter (0 until the first publish).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Atomically publish a replacement engine; returns the new epoch.
    ///
    /// Panics if the replacement serves a different [`super::VariantSpec`]
    /// than the current engine — an epoch swap recalibrates a variant, it
    /// never changes what the variant *is*.
    pub fn publish(&self, next: Arc<dyn Engine>) -> u64 {
        let mut guard = self.engine.write().unwrap();
        assert_eq!(
            guard.spec(),
            next.spec(),
            "epoch swap must preserve the variant spec"
        );
        *guard = next;
        // Bumped inside the write critical section so `current()` can never
        // pair the new engine with the old epoch or vice versa.
        self.epoch.fetch_add(1, Ordering::AcqRel) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FloatEngine;
    use crate::nn::Graph;
    use crate::tensor::Shape;

    fn engine() -> Arc<dyn Engine> {
        let mut g = Graph::new(Shape::hwc(2, 2, 1));
        let x = g.input();
        let r = g.relu(x);
        g.mark_output(r);
        Arc::new(FloatEngine::new(Arc::new(g)))
    }

    #[test]
    fn publish_bumps_epoch_and_swaps_engine() {
        let cell = EngineCell::new(engine());
        assert_eq!(cell.epoch(), 0);
        let (e0, first) = cell.current();
        assert_eq!(e0, 0);
        let second = engine();
        assert_eq!(cell.publish(Arc::clone(&second)), 1);
        let (e1, current) = cell.current();
        assert_eq!(e1, 1);
        assert!(Arc::ptr_eq(&current, &second));
        assert!(!Arc::ptr_eq(&current, &first));
        // The displaced engine is still alive for in-flight holders.
        assert_eq!(first.spec(), current.spec());
    }

    #[test]
    #[should_panic(expected = "preserve the variant spec")]
    fn publish_refuses_spec_changes() {
        use crate::engine::QuantEngine;
        use crate::nn::quant_exec::{QuantExecutor, QuantSettings};
        use crate::nn::QuantMode;

        let cell = EngineCell::new(engine());
        let mut g = Graph::new(Shape::hwc(2, 2, 1));
        let x = g.input();
        let r = g.relu(x);
        g.mark_output(r);
        let ex = QuantExecutor::new(
            Arc::new(g),
            QuantSettings { mode: QuantMode::Dynamic, ..Default::default() },
        );
        cell.publish(Arc::new(QuantEngine::new(Arc::new(ex))));
    }
}
