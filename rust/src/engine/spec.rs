//! Variant identity: what a served model variant executes, and its stable
//! wire name.
//!
//! [`VariantSpec`] is the single source of truth for "which execution
//! strategy" — fp32, fake-quant emulation at a granularity, or true int8
//! with a weight-scale granularity — replacing the parallel
//! `ExecKind`/`ArenaKind`/`ModeKey` enums the coordinator used to keep in
//! sync by hand. [`VariantKey`] pairs a spec with a model name and owns the
//! `<model>|<mode>` naming clients put on the wire (`m|fp32`, `m|ours-t`,
//! `m|int8-static-c`, ...). The wire grammar is unchanged from the
//! pre-redesign `ModeKey`, so existing clients keep working; int8 variants
//! additionally carry a nested truncation rung (`bits` ∈ {8, 4, 2}) with
//! the 8-bit rung spelled exactly as before and the degraded rungs
//! suffixed `@4` / `@2` (`m|int8-static-c@4`).

use crate::nn::QuantMode;
use crate::quant::Granularity;

/// Which execution strategy a variant uses. `Copy`, totally ordered, and
/// hashable so it can key routers and catalogs directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum VariantSpec {
    /// Full-precision reference path (the in-process float engine).
    Fp32,
    /// Calibrated quantization emulation (f32 carriers, §5.2's
    /// "custom-made quantization API").
    FakeQuant {
        /// Pre-activation requantization strategy (Fig. 1).
        mode: QuantMode,
        /// Activation-grid granularity.
        gran: Granularity,
    },
    /// True-int8 execution on the integer-native engine. Activations are
    /// per-tensor by construction (the CMSIS convention); the granularity
    /// here names the *weight* scales.
    Int8 {
        /// Pre-activation requantization strategy (Fig. 1).
        mode: QuantMode,
        /// Weight-scale granularity.
        weight_gran: Granularity,
        /// Effective weight bit-width of the nested truncation rung
        /// (8 = the full program, 4/2 = the brownout degradation rungs
        /// derived from the same weight copy).
        bits: u32,
    },
}

/// Strict wire token for a mode (`static` | `dynamic` | `ours`); the
/// parser rejects the `FromStr` aliases so wire names stay canonical.
fn parse_mode_wire(s: &str) -> Result<QuantMode, String> {
    match s {
        "static" => Ok(QuantMode::Static),
        "dynamic" => Ok(QuantMode::Dynamic),
        "ours" => Ok(QuantMode::Probabilistic),
        other => Err(format!("unknown quant mode {other:?}")),
    }
}

fn gran_wire(g: Granularity) -> &'static str {
    match g {
        Granularity::PerTensor => "t",
        Granularity::PerChannel => "c",
    }
}

fn parse_gran_wire(s: &str) -> Result<Granularity, String> {
    match s {
        "t" => Ok(Granularity::PerTensor),
        "c" => Ok(Granularity::PerChannel),
        other => Err(format!("unknown granularity {other:?}")),
    }
}

impl VariantSpec {
    /// Every representable spec: fp32 + {3 modes × 2 granularities} for
    /// the fake-quant backend, and {3 modes × 2 granularities × 3 rungs}
    /// for the int8 backend (25 total). Menus and the wire round-trip
    /// property test enumerate this.
    pub fn all() -> Vec<VariantSpec> {
        let mut out = vec![VariantSpec::Fp32];
        for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
            for gran in [Granularity::PerTensor, Granularity::PerChannel] {
                out.push(VariantSpec::FakeQuant { mode, gran });
                for bits in [8u32, 4, 2] {
                    out.push(VariantSpec::Int8 { mode, weight_gran: gran, bits });
                }
            }
        }
        out
    }

    /// Stable wire name: `fp32`, `<mode>-<gran>`, `int8-<mode>-<gran>`
    /// (8-bit — spelled exactly as before rungs existed), or
    /// `int8-<mode>-<gran>@<bits>` for the 4/2-bit rungs
    /// ([`VariantSpec::parse_wire`] is the exact inverse).
    pub fn wire(&self) -> String {
        match self {
            VariantSpec::Fp32 => "fp32".into(),
            VariantSpec::FakeQuant { mode, gran } => {
                format!("{}-{}", mode.label(), gran_wire(*gran))
            }
            VariantSpec::Int8 { mode, weight_gran, bits: 8 } => {
                format!("int8-{}-{}", mode.label(), gran_wire(*weight_gran))
            }
            VariantSpec::Int8 { mode, weight_gran, bits } => {
                format!("int8-{}-{}@{}", mode.label(), gran_wire(*weight_gran), bits)
            }
        }
    }

    /// Parse a wire name produced by [`VariantSpec::wire`]; anything else
    /// is a descriptive `Err`. `@8` is rejected (the canonical 8-bit
    /// spelling has no suffix), as is `@` on any non-int8 variant.
    pub fn parse_wire(s: &str) -> Result<VariantSpec, String> {
        if s == "fp32" {
            return Ok(VariantSpec::Fp32);
        }
        let (base, bits) = match s.split_once('@') {
            Some((head, b)) => match b {
                "4" => (head, 4u32),
                "2" => (head, 2),
                other => {
                    return Err(format!(
                        "unknown rung @{other:?} (want @4 | @2; the 8-bit rung has no suffix)"
                    ))
                }
            },
            None => (s, 8),
        };
        let parts: Vec<&str> = base.split('-').collect();
        match parts.as_slice() {
            [m, g] if bits == 8 => {
                Ok(VariantSpec::FakeQuant { mode: parse_mode_wire(m)?, gran: parse_gran_wire(g)? })
            }
            ["int8", m, g] => Ok(VariantSpec::Int8 {
                mode: parse_mode_wire(m)?,
                weight_gran: parse_gran_wire(g)?,
                bits,
            }),
            _ => Err(format!(
                "unknown mode {s:?} (want fp32 | <mode>-<gran> | int8-<mode>-<gran>[@4|@2])"
            )),
        }
    }

    /// Human-readable label (display only — never parsed): `fp32`,
    /// `ours/T`, `int8/static/C`, `int8/static/C@4`, ...
    pub fn label(&self) -> String {
        match self {
            VariantSpec::Fp32 => "fp32".into(),
            VariantSpec::FakeQuant { mode, gran } => {
                format!("{}/{}", mode.label(), gran.label())
            }
            VariantSpec::Int8 { mode, weight_gran, bits: 8 } => {
                format!("int8/{}/{}", mode.label(), weight_gran.label())
            }
            VariantSpec::Int8 { mode, weight_gran, bits } => {
                format!("int8/{}/{}@{}", mode.label(), weight_gran.label(), bits)
            }
        }
    }

    /// Effective precision this variant serves at, for the response
    /// preamble and the `pdq_precision_served_total{bits}` metric: 32 for
    /// fp32, 8 for fake-quant emulation (f32 carriers of exactly-quantized
    /// 8-bit values), and the rung width for int8.
    pub fn precision_bits(&self) -> u32 {
        match self {
            VariantSpec::Fp32 => 32,
            VariantSpec::FakeQuant { .. } => 8,
            VariantSpec::Int8 { bits, .. } => *bits,
        }
    }

    /// The same variant at a different truncation rung, when that makes
    /// sense: int8 specs swap their `bits`, everything else has no rungs
    /// (`None`). The brownout ladder walks this.
    pub fn at_bits(&self, bits: u32) -> Option<VariantSpec> {
        match self {
            VariantSpec::Int8 { mode, weight_gran, .. } => {
                Some(VariantSpec::Int8 { mode: *mode, weight_gran: *weight_gran, bits })
            }
            _ => None,
        }
    }
}

/// Full variant identity: a model name plus its [`VariantSpec`].
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct VariantKey {
    /// The served model's name (must not contain `'|'`).
    pub model: String,
    /// The execution strategy.
    pub spec: VariantSpec,
}

/// Cap on wire model names. Wire names arrive from untrusted clients (the
/// `/v1/infer` preamble, query params); without a cap a hostile client can
/// make the server allocate and echo megabyte "model names" into catalogs,
/// metrics labels and error bodies.
pub const MAX_MODEL_NAME_BYTES: usize = 64;

/// Charset for wire model names: ASCII alphanumerics plus `_` `.` `-`.
/// Matches every model the repo serves and keeps names safe to embed in
/// Prometheus labels, JSON and log lines without escaping.
fn valid_model_name(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= MAX_MODEL_NAME_BYTES
        && s.bytes().all(|b| b.is_ascii_alphanumeric() || matches!(b, b'_' | b'.' | b'-'))
}

impl VariantKey {
    /// Build a key from a model name and a spec.
    pub fn new(model: impl Into<String>, spec: VariantSpec) -> VariantKey {
        VariantKey { model: model.into(), spec }
    }

    /// Display label: `<model>/<spec label>` (worker thread names, tables).
    pub fn label(&self) -> String {
        format!("{}/{}", self.model, self.spec.label())
    }

    /// `<model>|<spec wire>` — the name clients put on the wire.
    pub fn wire(&self) -> String {
        format!("{}|{}", self.model, self.spec.wire())
    }

    /// Parse a wire name produced by [`VariantKey::wire`]. Model names are
    /// validated (length- and charset-capped) because this is the entry
    /// point for untrusted client bytes; [`VariantKey::new`] stays
    /// unvalidated for programmer-side construction.
    pub fn parse_wire(s: &str) -> Result<VariantKey, String> {
        let (model, mode) =
            s.split_once('|').ok_or_else(|| format!("variant {s:?} missing '|' separator"))?;
        if !valid_model_name(model) {
            return Err(format!(
                "bad model name (want 1..={MAX_MODEL_NAME_BYTES} bytes of [A-Za-z0-9_.-], got {} bytes)",
                model.len()
            ));
        }
        Ok(VariantKey { model: model.to_string(), spec: VariantSpec::parse_wire(mode)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::Checker;

    #[test]
    fn wire_roundtrips_every_representable_spec() {
        let specs = VariantSpec::all();
        assert_eq!(specs.len(), 25, "1 fp32 + 3x2 fake-quant + 3x2x3 int8 rungs");
        for spec in specs {
            let key = VariantKey::new("micro_resnet", spec);
            let wire = key.wire();
            assert_eq!(VariantKey::parse_wire(&wire).unwrap(), key, "roundtrip {wire}");
            assert_eq!(VariantSpec::parse_wire(&spec.wire()).unwrap(), spec);
        }
        // Spot-check the grammar is byte-stable (serving clients depend on
        // it): the 8-bit rung keeps the exact pre-rung spelling.
        assert_eq!(VariantSpec::Fp32.wire(), "fp32");
        assert_eq!(
            VariantSpec::Int8 {
                mode: QuantMode::Probabilistic,
                weight_gran: Granularity::PerChannel,
                bits: 8
            }
            .wire(),
            "int8-ours-c"
        );
        assert_eq!(
            VariantKey::parse_wire("m|int8-ours-c").unwrap().spec,
            VariantSpec::Int8 {
                mode: QuantMode::Probabilistic,
                weight_gran: Granularity::PerChannel,
                bits: 8
            }
        );
        assert_eq!(
            VariantSpec::Int8 {
                mode: QuantMode::Static,
                weight_gran: Granularity::PerChannel,
                bits: 4
            }
            .wire(),
            "int8-static-c@4"
        );
        assert_eq!(
            VariantKey::parse_wire("m|int8-static-t@2").unwrap().spec,
            VariantSpec::Int8 {
                mode: QuantMode::Static,
                weight_gran: Granularity::PerTensor,
                bits: 2
            }
        );
    }

    #[test]
    fn precision_bits_and_rung_swaps() {
        assert_eq!(VariantSpec::Fp32.precision_bits(), 32);
        let fq = VariantSpec::FakeQuant {
            mode: QuantMode::Probabilistic,
            gran: Granularity::PerTensor,
        };
        assert_eq!(fq.precision_bits(), 8);
        assert!(fq.at_bits(4).is_none(), "only int8 has rungs");
        assert!(VariantSpec::Fp32.at_bits(4).is_none());
        let base = VariantSpec::Int8 {
            mode: QuantMode::Static,
            weight_gran: Granularity::PerTensor,
            bits: 8,
        };
        let r4 = base.at_bits(4).unwrap();
        assert_eq!(r4.precision_bits(), 4);
        assert_eq!(r4.wire(), "int8-static-t@4");
        assert_eq!(r4.at_bits(8), Some(base), "rung swap is reversible");
    }

    /// Property: for random model names over the serving charset and every
    /// representable spec, `wire` and `parse_wire` are exact inverses.
    #[test]
    fn prop_wire_roundtrip_random_models() {
        let charset: Vec<char> = "abcdefghijklmnopqrstuvwxyz0123456789_.-".chars().collect();
        let specs = VariantSpec::all();
        Checker::new(0x5EC5, 256).check("variant wire roundtrip", |rng| {
            let len = rng.int_range(1, 24) as usize;
            let model: String = (0..len).map(|_| *rng.choice(&charset)).collect();
            let spec = *rng.choice(&specs);
            let key = VariantKey { model, spec };
            let wire = key.wire();
            let back = VariantKey::parse_wire(&wire).map_err(|e| format!("{wire:?}: {e}"))?;
            if back != key {
                return Err(format!("{wire:?} parsed to {back:?}, want {key:?}"));
            }
            Ok(())
        });
    }

    /// Property: corrupting any valid wire name in structural ways
    /// (dropping the separator, emptying the model, mangling the mode
    /// token, appending a segment) must produce a parse error, never a
    /// silently different variant.
    #[test]
    fn prop_malformed_wires_rejected() {
        let specs = VariantSpec::all();
        Checker::new(0xBAD1, 256).check("malformed wire rejected", |rng| {
            let spec = *rng.choice(&specs);
            let key = VariantKey::new("m", spec);
            let wire = key.wire();
            let bad = match rng.int_range(0, 3) {
                0 => wire.replace('|', ""),
                1 => format!("|{}", spec.wire()),
                2 => format!("m|x{}", spec.wire()),
                _ => format!("{wire}-zz"),
            };
            match VariantKey::parse_wire(&bad) {
                Err(_) => Ok(()),
                Ok(k) => Err(format!("{bad:?} parsed to {k:?}")),
            }
        });
    }

    #[test]
    fn malformed_wire_fixtures_rejected() {
        for bad in [
            "",
            "no-separator",
            "m|",
            "m|int9-ours-t",
            "m|ours",
            "m|ours-x",
            "|fp32",
            "m|probabilistic-t", // FromStr alias, not a wire token
            "m|OURS-T",          // wire names are case-sensitive
            "m|int8-ours",
            "m|int8--t",
            "m|fp32-t",
            "m|int8-ours-t@8", // canonical 8-bit spelling has no suffix
            "m|int8-ours-t@3",
            "m|int8-ours-t@0",
            "m|int8-ours-t@",
            "m|int8-ours-t@44",
            "m|ours-t@4",  // rungs are an int8 notion
            "m|fp32@4",
        ] {
            assert!(VariantKey::parse_wire(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn hostile_model_names_rejected() {
        // Unbounded model names would be allocated and echoed into
        // catalogs, metrics labels and error bodies.
        let huge = format!("{}|fp32", "a".repeat(1024 * 1024));
        assert!(VariantKey::parse_wire(&huge).is_err());
        let just_over = format!("{}|fp32", "a".repeat(MAX_MODEL_NAME_BYTES + 1));
        assert!(VariantKey::parse_wire(&just_over).is_err());
        let at_cap = format!("{}|fp32", "a".repeat(MAX_MODEL_NAME_BYTES));
        assert!(VariantKey::parse_wire(&at_cap).is_ok());
        // Charset: no spaces, control bytes, quotes, or non-ASCII.
        for bad in ["a b|fp32", "a\"b|fp32", "a\nb|fp32", "café|fp32", "a{}|fp32"] {
            assert!(VariantKey::parse_wire(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn labels_are_human_readable() {
        let k = VariantKey::new(
            "m",
            VariantSpec::FakeQuant {
                mode: QuantMode::Probabilistic,
                gran: Granularity::PerTensor,
            },
        );
        assert_eq!(k.label(), "m/ours/T");
        assert_eq!(VariantKey::new("m", VariantSpec::Fp32).label(), "m/fp32");
    }
}
