//! [`EngineBuilder`]: the one construction path for every variant.
//!
//! Subsumes what used to be scattered across `coordinator/calibrate.rs`
//! (`build_quant_variant` / `build_int8_variant`) and `main.rs`
//! (`serve_variants`): pick a model, a [`VariantSpec`], the knobs (γ,
//! bits, coverage), and a calibration source, and get back a boxed
//! [`Engine`] — with every unbuildable combination surfacing as a typed
//! [`EngineError`] instead of a panic or an ad-hoc `String`.

use std::sync::Arc;

use super::backends::{FloatEngine, Int8Engine, QuantEngine};
use super::{Engine, EngineError, VariantKey, VariantSpec};
use crate::data::{shapes, Task};
use crate::models::Model;
use crate::nn::quant_exec::{QuantExecutor, QuantSettings};
use crate::nn::{Int8Executor, QuantMode};
use crate::quant::Granularity;
use crate::tensor::Tensor;

/// The paper's calibration-set size (§5.2): the *same* 16 images feed
/// static quantization and the probabilistic interval fit.
pub const CALIB_SIZE: usize = 16;

/// Calibration images for a task (the shared set).
pub fn calibration_images(task: Task, n: usize) -> Vec<Tensor<f32>> {
    shapes::dataset(task, shapes::Split::Calib, n).iter().map(|s| s.image_f32()).collect()
}

/// Fluent builder for one model variant. All knobs default to the paper's
/// settings; calibration images default to the model task's shared
/// [`CALIB_SIZE`]-image set.
pub struct EngineBuilder<'m> {
    model: &'m Model,
    spec: VariantSpec,
    gamma: usize,
    bits: u32,
    coverage: f32,
    calib: Option<Vec<Tensor<f32>>>,
    calib_size: usize,
}

impl<'m> EngineBuilder<'m> {
    /// Start building a variant of `model` (defaults to [`VariantSpec::Fp32`]).
    pub fn new(model: &'m Model) -> EngineBuilder<'m> {
        let d = QuantSettings::default();
        EngineBuilder {
            model,
            spec: VariantSpec::Fp32,
            gamma: 1,
            bits: d.bits,
            coverage: d.coverage,
            calib: None,
            calib_size: CALIB_SIZE,
        }
    }

    /// Which execution strategy to build.
    pub fn spec(mut self, spec: VariantSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Sampling stride γ for the probabilistic estimator (§4.2).
    pub fn gamma(mut self, gamma: usize) -> Self {
        self.gamma = gamma;
        self
    }

    /// Quantization bit-width (fake-quant only; int8 lowering requires 8).
    pub fn bits(mut self, bits: u32) -> Self {
        self.bits = bits;
        self
    }

    /// Target coverage for the Eq. 13 interval calibration.
    pub fn coverage(mut self, coverage: f32) -> Self {
        self.coverage = coverage;
        self
    }

    /// Use this explicit calibration set instead of the task default.
    pub fn calibration_images(mut self, images: &[Tensor<f32>]) -> Self {
        self.calib = Some(images.to_vec());
        self
    }

    /// Size of the auto-generated task calibration set (ignored when an
    /// explicit set was supplied).
    pub fn calibration_size(mut self, n: usize) -> Self {
        self.calib_size = n;
        self
    }

    /// The [`VariantKey`] this builder's engine will serve under.
    pub fn key(&self) -> VariantKey {
        VariantKey { model: self.model.name.clone(), spec: self.spec }
    }

    /// Take the calibration set out of the (consumed) builder — moves the
    /// supplied images instead of cloning them per build.
    fn take_calib(&mut self) -> Vec<Tensor<f32>> {
        self.calib
            .take()
            .unwrap_or_else(|| calibration_images(self.model.task, self.calib_size))
    }

    fn validate(&self) -> Result<(), EngineError> {
        if self.gamma == 0 {
            return Err(EngineError::InvalidSpec("gamma must be >= 1".into()));
        }
        if !(2..=8).contains(&self.bits) {
            return Err(EngineError::InvalidSpec(format!(
                "bits must be in 2..=8, got {}",
                self.bits
            )));
        }
        Ok(())
    }

    /// Assemble emulator settings from the builder knobs.
    fn quant_settings(&self, mode: QuantMode, gran: Granularity) -> QuantSettings {
        QuantSettings {
            mode,
            granularity: gran,
            bits: self.bits,
            gamma: self.gamma,
            coverage: self.coverage,
        }
    }

    /// Build the calibrated fake-quant executor behind a
    /// [`VariantSpec::FakeQuant`] spec — the escape hatch for drivers that
    /// mutate the executor before serving (the A1/A2 ablations). Other
    /// specs return [`EngineError::InvalidSpec`].
    pub fn build_executor(mut self) -> Result<QuantExecutor, EngineError> {
        self.validate()?;
        let VariantSpec::FakeQuant { mode, gran } = self.spec else {
            return Err(EngineError::InvalidSpec(format!(
                "build_executor() needs a FakeQuant spec, got {:?}",
                self.spec
            )));
        };
        let settings = self.quant_settings(mode, gran);
        let mut ex = QuantExecutor::new(Arc::clone(&self.model.graph), settings);
        ex.calibrate(&self.take_calib());
        Ok(ex)
    }

    /// Build the engine.
    pub fn build(mut self) -> Result<Arc<dyn Engine>, EngineError> {
        self.validate()?;
        match self.spec {
            VariantSpec::Fp32 => Ok(Arc::new(FloatEngine::new(Arc::clone(&self.model.graph)))),
            VariantSpec::FakeQuant { .. } => {
                let ex = self.build_executor()?;
                Ok(Arc::new(QuantEngine::new(Arc::new(ex))))
            }
            VariantSpec::Int8 { mode, weight_gran, bits } => {
                // The f32 emulator is calibration scaffolding only: int8
                // activations are per-tensor by construction (CMSIS).
                let settings = self.quant_settings(mode, Granularity::PerTensor);
                let mut ex = QuantExecutor::new(Arc::clone(&self.model.graph), settings);
                ex.calibrate(&self.take_calib());
                let int8 =
                    Int8Executor::lower(&ex, weight_gran).map_err(EngineError::InvalidSpec)?;
                // The truncation rungs derive from the full 8-bit program
                // (the spec's `bits`, not the fake-quant emulator knob).
                let int8 = if bits == 8 {
                    int8
                } else {
                    int8.rung(bits).map_err(EngineError::InvalidSpec)?
                };
                Ok(Arc::new(Int8Engine::new(Arc::new(int8))))
            }
        }
    }

    /// Build the engine together with its serving [`VariantKey`].
    pub fn build_variant(self) -> Result<(VariantKey, Arc<dyn Engine>), EngineError> {
        let key = self.key();
        Ok((key, self.build()?))
    }

    /// Pack this builder's model + knobs into `pdq-artifact-v1` bytes.
    ///
    /// An artifact always carries the model's *entire* 13-cell menu, so
    /// the builder's `spec` only contributes its weight granularity (when
    /// it is an int8 spec; per-tensor otherwise). Calibration images, γ
    /// and coverage are the builder's. The serve-side counterpart is
    /// [`crate::artifact::ArtifactEngine`].
    pub fn pack(mut self) -> Result<Vec<u8>, crate::artifact::ArtifactError> {
        let weight_gran = match self.spec {
            VariantSpec::Int8 { weight_gran, .. } => weight_gran,
            _ => Granularity::PerTensor,
        };
        let opts = crate::artifact::PackOptions {
            gamma: self.gamma,
            coverage: self.coverage,
            weight_gran,
            calib: Some(self.take_calib()),
            ..crate::artifact::PackOptions::default()
        };
        crate::artifact::pack_model(self.model, opts)
    }
}

/// The standard serving menu for one model: fp32 plus the paper's three
/// requantization modes, each as fake-quant emulation and as true int8
/// (per-tensor grids, all three truncation rungs so the brownout ladder
/// has somewhere to step), all sharing one calibration set — what
/// `pdq serve` registers.
pub fn standard_menu(model: &Model) -> Result<Vec<(VariantKey, Arc<dyn Engine>)>, EngineError> {
    let calib = calibration_images(model.task, CALIB_SIZE);
    let mut out = vec![EngineBuilder::new(model).calibration_images(&calib).build_variant()?];
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        out.push(
            EngineBuilder::new(model)
                .spec(VariantSpec::FakeQuant { mode, gran: Granularity::PerTensor })
                .calibration_images(&calib)
                .build_variant()?,
        );
    }
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        for bits in [8u32, 4, 2] {
            out.push(
                EngineBuilder::new(model)
                    .spec(VariantSpec::Int8 { mode, weight_gran: Granularity::PerTensor, bits })
                    .calibration_images(&calib)
                    .build_variant()?,
            );
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::calibrate::demo_model;

    #[test]
    fn builder_rejects_bad_knobs() {
        let model = demo_model("m");
        assert!(matches!(
            EngineBuilder::new(&model).gamma(0).build(),
            Err(EngineError::InvalidSpec(_))
        ));
        assert!(matches!(
            EngineBuilder::new(&model)
                .spec(VariantSpec::FakeQuant {
                    mode: QuantMode::Static,
                    gran: Granularity::PerTensor
                })
                .bits(1)
                .build(),
            Err(EngineError::InvalidSpec(_))
        ));
        // Int8 lowering refuses non-8-bit *grids* with a typed error: the
        // builder's `.bits()` knob is the fake-quant emulator width, not
        // the rung (that lives on the spec).
        assert!(matches!(
            EngineBuilder::new(&model)
                .spec(VariantSpec::Int8 {
                    mode: QuantMode::Static,
                    weight_gran: Granularity::PerTensor,
                    bits: 8
                })
                .bits(4)
                .build(),
            Err(EngineError::InvalidSpec(_))
        ));
        assert!(matches!(
            EngineBuilder::new(&model).build_executor(),
            Err(EngineError::InvalidSpec(_)),
        ));
    }

    #[test]
    fn standard_menu_builds_all_thirteen_variants() {
        let model = demo_model("demo");
        let menu = standard_menu(&model).expect("menu builds");
        assert_eq!(menu.len(), 13);
        let wires: Vec<String> = menu.iter().map(|(k, _)| k.wire()).collect();
        assert!(wires.contains(&"demo|fp32".to_string()));
        assert!(wires.contains(&"demo|ours-t".to_string()));
        assert!(wires.contains(&"demo|int8-ours-t".to_string()));
        assert!(wires.contains(&"demo|int8-static-t@4".to_string()));
        assert!(wires.contains(&"demo|int8-ours-t@2".to_string()));
        for (key, engine) in &menu {
            assert_eq!(key.spec, engine.spec(), "key and engine must agree");
            let mut session = engine.compile().expect("compiles");
            let img = calibration_images(model.task, 1).remove(0);
            let out = session.run(&img).expect("runs");
            assert_eq!(out[0].shape().dims(), &[10]);
        }
    }

    #[test]
    fn builder_key_matches_built_engine_spec() {
        let model = demo_model("m");
        for spec in VariantSpec::all() {
            let b = EngineBuilder::new(&model).spec(spec).calibration_size(4);
            assert_eq!(b.key().spec, spec);
        }
    }
}
