//! Coverage-guided mirror of `fuzz_smoke::fuzz_wire_preamble_decoding`:
//! decode must never panic, and anything that decodes must survive an
//! encode → decode round trip unchanged.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    pdq::testing::fuzz::target_wire_preamble(data);
});
