//! Coverage-guided mirror of `fuzz_smoke::fuzz_slo_query_parsing`:
//! `SloQuery::parse` must never panic, every accepted query must respect
//! the documented bounds, and the canonical `render` must reparse to the
//! identical query.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    pdq::testing::fuzz::target_slo_query(data);
});
