//! Coverage-guided mirror of `fuzz_smoke::fuzz_artifact_payload_loading`:
//! `ArtifactEngine::from_bytes` must never panic on arbitrary bytes, and
//! anything that loads must also pass `inspect_bytes` and carry a menu
//! whose keys agree with the engines behind them. Seed the corpus with a
//! packed artifact (`pdq pack --synthetic --out corpus/seed.pdqa`) so the
//! fuzzer starts past the magic/CRC outer wall.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    pdq::testing::fuzz::target_artifact_payload(data);
});
