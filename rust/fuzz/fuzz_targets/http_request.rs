//! Coverage-guided mirror of `fuzz_smoke::fuzz_http_request_parsing`:
//! whole-buffer vs. stuttered split reads must parse identically and
//! never panic. Seed corpus: any bytes; the target is total.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    pdq::testing::fuzz::target_http_request(data);
});
