//! Coverage-guided mirror of `fuzz_smoke::fuzz_variant_key_wire_parsing`:
//! `VariantKey::parse_wire` must never panic, and every accepted key must
//! round-trip through `wire()` to an equal key.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    pdq::testing::fuzz::target_variant_wire(data);
});
