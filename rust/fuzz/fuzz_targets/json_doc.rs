//! Coverage-guided mirror of `fuzz_smoke::fuzz_json_documents`: the JSON
//! parser must never panic (including deep-nesting stack overflow, which
//! libfuzzer catches as a crash) and compact serialization must be a
//! fixed point under reparsing.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    pdq::testing::fuzz::target_json(data);
});
