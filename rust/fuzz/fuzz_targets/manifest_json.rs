//! Coverage-guided mirror of `fuzz_smoke::fuzz_artifact_manifest_json`:
//! `Manifest::parse` must never panic on arbitrary text, any manifest it
//! accepts must `validate()` without panicking against arbitrary payload
//! lengths, and serialization must be a fixed point under reparsing.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    pdq::testing::fuzz::target_manifest_json(data);
});
