//! Coverage-guided mirror of `fuzz_smoke::fuzz_autopilot_config_grammar`:
//! `AutopilotConfig::parse` must never panic (first 8 bytes are the
//! little-endian budget, the rest the spec), every accepted config must
//! satisfy the control law's preconditions, and the canonical `render`
//! must reparse to the identical config.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    pdq::testing::fuzz::target_autopilot_config(data);
});
