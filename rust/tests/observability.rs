//! Flight-recorder end-to-end tests: request tracing over real sockets.
//!
//! What is proven here:
//! 1. an armed front door echoes a client-supplied `X-PDQ-Trace` ID and
//!    `GET /v1/traces?id=` returns the full stage breakdown — accept →
//!    parse → admit → queue → batch → execute → serialize — with
//!    per-node kernel spans on an int8 variant, spans in pipeline order,
//!    and the stage sum bounded by the end-to-end total;
//! 2. the trace ID also rides the binary wire preamble (the `"trace"`
//!    field) both directions, for clients that can't set headers;
//! 3. with tracing disarmed (the default), responses are bit-identical
//!    to an armed server's, carry no trace field or header, and
//!    `/v1/traces` is 404 — tracing is observably zero-cost when off;
//! 4. a malformed body on an armed server still leaves an anomalous
//!    trace behind (outcome `error`), so hostile traffic is on record.
//!
//! Ring-eviction behavior (anomalies survive wrap-around) is unit-tested
//! in `pdq::obs::recorder`; `X-PDQ-Trace` parsing is fuzzed in
//! `rust/tests/fuzz_smoke.rs`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use pdq::coordinator::{Server, ServerConfig};
use pdq::engine::{Engine, Int8Engine, QuantEngine, VariantKey, VariantSpec};
use pdq::net::wire::{self, Client, InferOutcome};
use pdq::net::{FrontDoor, FrontDoorConfig};
use pdq::nn::int8_exec::Int8Executor;
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{Graph, QuantMode};
use pdq::obs::TraceId;
use pdq::quant::Granularity;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::json::Json;
use pdq::util::Pcg32;

const HW: usize = 8;
const CIN: usize = 2;

/// conv(2→4, 3x3) → relu → gap, input 8×8×2; weights seeded, so two
/// builds (armed server, disarmed server) are bit-identical engines.
fn test_graph() -> Arc<Graph> {
    let mut rng = Pcg32::new(0xF00D);
    let mut g = Graph::new(Shape::hwc(HW, HW, CIN));
    let x = g.input();
    let w: Vec<f32> = (0..4 * 9 * CIN).map(|_| rng.normal_ms(0.0, 0.3)).collect();
    let c = g.conv(
        x,
        Tensor::from_vec(Shape::ohwi(4, 3, 3, CIN), w),
        vec![0.05, -0.05, 0.0, 0.1],
        ConvGeom::same(3, 1),
    );
    let r = g.relu(c);
    let p = g.global_avg_pool(r);
    g.mark_output(p);
    Arc::new(g)
}

fn calib_images() -> Vec<Tensor<f32>> {
    let mut rng = Pcg32::new(0xCA11);
    (0..8)
        .map(|_| {
            let d: Vec<f32> = (0..HW * HW * CIN).map(|_| rng.uniform()).collect();
            Tensor::from_vec(Shape::hwc(HW, HW, CIN), d)
        })
        .collect()
}

fn build_variant(spec: &VariantSpec) -> (VariantKey, Arc<dyn Engine>) {
    let key = VariantKey::new("t", *spec);
    let graph = test_graph();
    let engine: Arc<dyn Engine> = match *spec {
        VariantSpec::Fp32 => Arc::new(pdq::engine::FloatEngine::new(graph)),
        VariantSpec::FakeQuant { mode, gran } => {
            let mut ex = QuantExecutor::new(
                graph,
                QuantSettings { mode, granularity: gran, ..Default::default() },
            );
            ex.calibrate(&calib_images());
            Arc::new(QuantEngine::new(Arc::new(ex)))
        }
        VariantSpec::Int8 { mode, weight_gran, bits: _ } => {
            let mut ex = QuantExecutor::new(
                graph,
                QuantSettings { mode, granularity: Granularity::PerTensor, ..Default::default() },
            );
            ex.calibrate(&calib_images());
            Arc::new(Int8Engine::new(Arc::new(
                Int8Executor::lower(&ex, weight_gran).expect("lowering"),
            )))
        }
    };
    (key, engine)
}

fn int8_key() -> VariantKey {
    VariantKey::new(
        "t",
        VariantSpec::Int8 {
            mode: QuantMode::Probabilistic,
            weight_gran: Granularity::PerTensor,
            bits: 8,
        },
    )
}

fn start_front_door(trace: bool) -> (FrontDoor, String) {
    let variants: Vec<(VariantKey, Arc<dyn Engine>)> =
        [VariantSpec::Fp32, int8_key().spec].iter().map(build_variant).collect();
    let server = Arc::new(Server::start(variants, ServerConfig::default()));
    let fd = FrontDoor::start(server, FrontDoorConfig { trace, ..Default::default() })
        .expect("bind ephemeral port");
    let addr = fd.local_addr().to_string();
    (fd, addr)
}

/// One raw HTTP/1.1 POST with an extra header — [`Client`] doesn't do
/// custom headers, and the `X-PDQ-Trace` precedence path needs one.
fn post_with_header(
    addr: &str,
    path: &str,
    header: (&str, &str),
    body: &[u8],
) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let head = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\n{}: {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        header.0,
        header.1,
        wire::TENSOR_CONTENT_TYPE,
        body.len(),
    );
    s.write_all(head.as_bytes()).unwrap();
    s.write_all(body).unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read response");
    let split = raw.windows(4).position(|w| w == b"\r\n\r\n").expect("header terminator");
    let head = std::str::from_utf8(&raw[..split]).expect("ascii head");
    let mut lines = head.split("\r\n");
    let status: u16 =
        lines.next().unwrap().split_whitespace().nth(1).unwrap().parse().unwrap();
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    (status, headers, raw[split + 4..].to_vec())
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

fn bits(t: &Tensor<f32>) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

/// Acceptance: client-supplied `X-PDQ-Trace` is echoed, and the recorder
/// serves the full span breakdown — kernel spans included — for an int8
/// request.
#[test]
fn traced_http_request_records_full_span_breakdown() {
    let (fd, addr) = start_front_door(true);
    let key = int8_key();
    let img = calib_images().remove(0);
    let id = "00000000deadbeef";

    let body = wire::encode_infer_request(&key, 7, &img);
    let (status, headers, resp_body) =
        post_with_header(&addr, "/v1/infer", ("X-PDQ-Trace", id), &body);
    assert_eq!(status, 200);
    assert_eq!(header(&headers, "x-pdq-trace"), Some(id), "header ID echoed verbatim");
    let resp = wire::decode_infer_response(&resp_body).expect("decode");
    assert_eq!(resp.trace.map(|t| t.to_string()).as_deref(), Some(id), "preamble echo too");

    let mut client = Client::new(&addr);
    let parts = client.get(&format!("/v1/traces?id={id}")).unwrap();
    assert_eq!(parts.status, 200);
    let j = Json::parse(std::str::from_utf8(&parts.body).unwrap()).unwrap();
    assert_eq!(j.get("schema").unwrap().as_str(), Some("pdq-traces-v1"));
    let traces = j.get("traces").unwrap().as_arr().unwrap();
    assert_eq!(traces.len(), 1, "the queried trace is on record");
    let t = &traces[0];
    assert_eq!(t.get("id").unwrap().as_str(), Some(id));
    assert_eq!(t.get("variant").unwrap().as_str(), Some(key.wire().as_str()));
    assert_eq!(t.get("request_id").unwrap().as_usize(), Some(7));
    assert_eq!(t.get("outcome").unwrap().as_str(), Some("ok"));
    assert_eq!(t.get("bits").unwrap().as_usize(), Some(8));

    let spans = t.get("spans").unwrap().as_arr().unwrap();
    let stages: Vec<&str> =
        spans.iter().filter_map(|s| s.get("stage").and_then(|v| v.as_str())).collect();
    for want in ["accept", "parse", "admit", "queue", "batch", "execute", "serialize"] {
        assert!(stages.contains(&want), "stage {want} missing from {stages:?}");
    }
    // Pipeline order, windows well-formed, and the per-stage sum can't
    // exceed the end-to-end total (stages tile the request, they don't
    // overlap it).
    let total_us = t.get("total_us").unwrap().as_f64().unwrap();
    let mut sum = 0.0;
    let mut prev_start = -1.0;
    for s in spans {
        let start = s.get("start_us").unwrap().as_f64().unwrap();
        let end = s.get("end_us").unwrap().as_f64().unwrap();
        assert!(end >= start, "span window is well-formed");
        assert!(start >= prev_start, "spans sorted by pipeline position");
        prev_start = start;
        sum += end - start;
    }
    assert!(total_us > 0.0);
    assert!(
        sum <= total_us * 1.05 + 50.0,
        "stage sum {sum:.1}µs exceeds total {total_us:.1}µs"
    );

    let kernel = t.get("kernel_spans").unwrap().as_arr().unwrap();
    assert!(!kernel.is_empty(), "int8 execution records per-node kernel spans");
    for k in kernel {
        assert!(k.get("op").unwrap().as_str().is_some());
        assert!(k.get("us").unwrap().as_f64().unwrap() >= 0.0);
    }

    fd.shutdown();
}

/// The trace ID rides the binary preamble both directions — no HTTP
/// headers involved — and lands in the recorder under that ID.
#[test]
fn wire_preamble_trace_round_trips_over_socket() {
    let (fd, addr) = start_front_door(true);
    let key = VariantKey::new("t", VariantSpec::Fp32);
    let img = calib_images().remove(0);
    let id = TraceId::parse("cafe").unwrap();

    let body = wire::encode_infer_request_traced(&key, 11, &img, Some(id));
    let mut client = Client::new(&addr);
    let parts =
        client.request("POST", "/v1/infer", wire::TENSOR_CONTENT_TYPE, &body).unwrap();
    assert_eq!(parts.status, 200);
    let resp = wire::decode_infer_response(&parts.body).expect("decode");
    assert_eq!(resp.trace, Some(id), "preamble trace echoed");
    assert_eq!(resp.id, 11);

    let got = client.get(&format!("/v1/traces?id={id}")).unwrap();
    let j = Json::parse(std::str::from_utf8(&got.body).unwrap()).unwrap();
    let traces = j.get("traces").unwrap().as_arr().unwrap();
    assert_eq!(traces.len(), 1);
    assert_eq!(traces[0].get("request_id").unwrap().as_usize(), Some(11));

    fd.shutdown();
}

/// Disarmed tracing (the default) is invisible on the wire and bit-exact:
/// same outputs as an armed server, no trace field or header, /v1/traces
/// is 404.
#[test]
fn disarmed_tracing_is_bit_identical_and_unqueryable() {
    let (fd_on, addr_on) = start_front_door(true);
    let (fd_off, addr_off) = start_front_door(false);
    let key = int8_key();
    let img = calib_images().remove(0);

    let body = wire::encode_infer_request(&key, 3, &img);
    let mut on = Client::new(&addr_on);
    let mut off = Client::new(&addr_off);
    let p_on = on.request("POST", "/v1/infer", wire::TENSOR_CONTENT_TYPE, &body).unwrap();
    let p_off = off.request("POST", "/v1/infer", wire::TENSOR_CONTENT_TYPE, &body).unwrap();
    assert_eq!(p_on.status, 200);
    assert_eq!(p_off.status, 200);

    let r_on = wire::decode_infer_response(&p_on.body).unwrap();
    let r_off = wire::decode_infer_response(&p_off.body).unwrap();
    assert!(r_on.trace.is_some(), "armed server mints and echoes an ID");
    assert!(p_on.header("x-pdq-trace").is_some());
    assert!(r_off.trace.is_none(), "disarmed response carries no trace field");
    assert!(p_off.header("x-pdq-trace").is_none(), "nor the header");
    assert_eq!(r_on.outputs.len(), r_off.outputs.len());
    for (a, b) in r_on.outputs.iter().zip(&r_off.outputs) {
        assert_eq!(bits(a), bits(b), "tracing must not perturb the numerics");
    }

    // Same deterministic request on the disarmed server again, through the
    // typed client: outputs stay bit-stable run to run.
    match off.post_infer(&key, 3, &img).unwrap() {
        InferOutcome::Ok(r2) => assert_eq!(bits(&r2.outputs[0]), bits(&r_off.outputs[0])),
        _ => panic!("unexpected non-OK outcome on an unloaded server"),
    }

    let missing = off.get("/v1/traces").unwrap();
    assert_eq!(missing.status, 404, "recorder endpoint is dark when disarmed");
    let armed = on.get("/v1/traces").unwrap();
    assert_eq!(armed.status, 200);
    let j = Json::parse(std::str::from_utf8(&armed.body).unwrap()).unwrap();
    assert!(j.get("committed").unwrap().as_usize().unwrap() >= 1);

    fd_on.shutdown();
    fd_off.shutdown();
}

/// A malformed body on an armed server still leaves an anomalous trace
/// behind — outcome `error`, found by the client-chosen ID.
#[test]
fn malformed_request_leaves_anomalous_trace() {
    let (fd, addr) = start_front_door(true);
    let id = "0000000000000bad";
    let (status, _headers, _) =
        post_with_header(&addr, "/v1/infer", ("X-PDQ-Trace", id), b"not a tensor frame");
    // The 400 path commits the trace before the response is built; no echo
    // header is promised there, but the trace must be queryable.
    assert_eq!(status, 400);
    let mut client = Client::new(&addr);
    let parts = client.get(&format!("/v1/traces?id={id}")).unwrap();
    let j = Json::parse(std::str::from_utf8(&parts.body).unwrap()).unwrap();
    let traces = j.get("traces").unwrap().as_arr().unwrap();
    assert_eq!(traces.len(), 1, "hostile traffic is on record");
    assert_eq!(traces[0].get("outcome").unwrap().as_str(), Some("error"));
    // Error outcomes are anomalous by definition: the anomaly ring holds it.
    let all = client.get("/v1/traces").unwrap();
    let j = Json::parse(std::str::from_utf8(&all.body).unwrap()).unwrap();
    assert!(j.get("anomalies").unwrap().as_usize().unwrap() >= 1);

    fd.shutdown();
}
