//! Fuzz-found regression corpus.
//!
//! Every case here is a real crash, panic-path, or mis-parse found while
//! developing the `pdq::testing::fuzz` harness against the pre-hardening
//! parsers, replayed as a named test against the fixed code. The rule:
//! a fuzz finding is not "fixed" until its exact input lives here — the
//! corpus is the proof the same bug cannot come back silently.
//!
//! Each test documents the original failure mode in a comment.

use std::io::Cursor;

use pdq::artifact::{self, ArtifactEngine, ArtifactError, PackOptions};
use pdq::coordinator::calibrate::demo_model;
use pdq::engine::VariantKey;
use pdq::net::http::{HttpError, ReadOutcome, RequestReader};
use pdq::net::wire;
use pdq::util::json::Json;

/// Parse one request from a byte slice with a small body cap.
fn parse(bytes: &[u8]) -> Result<ReadOutcome, HttpError> {
    RequestReader::new(Cursor::new(bytes.to_vec()), 4096).read_request()
}

fn expect_reject(bytes: &[u8], why: &str) {
    match parse(bytes) {
        Err(_) => {}
        Ok(o) => panic!("{why}: expected a parse error, got {o:?}"),
    }
}

// ---- util/json.rs ----------------------------------------------------------

#[test]
fn json_deep_nesting_stack_overflow() {
    // Original failure: the recursive-descent parser had no depth cap, so
    // `[[[[...` recursed once per byte and overflowed the stack — a
    // process abort that catch_unwind cannot contain, killing the whole
    // connection-pool worker's process. Now rejected at MAX_PARSE_DEPTH.
    assert!(Json::parse(&"[".repeat(100_000)).is_err());
    let objs = "{\"a\":".repeat(50_000) + "1";
    assert!(Json::parse(&objs).is_err());
}

#[test]
fn json_unicode_escape_splits_utf8() {
    // Original failure: `\uXXXX` grabbed the next 4 *bytes* and fed them
    // to from_utf8().unwrap(); a multi-byte UTF-8 char inside the window
    // (here `é` = 0xC3 0xA9) split the char boundary and panicked.
    assert!(Json::parse("\"\\u12é\"").is_err());
    assert!(Json::parse("\"\\u123é\"").is_err());
    // Truncated escape at end of input: read past the buffer.
    assert!(Json::parse("\"\\u12").is_err());
}

#[test]
fn json_plus_prefixed_u_escape() {
    // Original failure: the escape used from_str_radix, which accepts a
    // leading '+', so `\u+123` parsed as if it were a valid escape —
    // a mis-parse (two different inputs, same document). Hex-digit-only
    // validation rejects it.
    assert!(Json::parse("\"\\u+123\"").is_err());
    // The well-formed neighbors still work.
    assert_eq!(Json::parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
}

// ---- net/http.rs -----------------------------------------------------------

#[test]
fn content_length_plus_sign() {
    // Original failure: `"+5".parse::<usize>()` succeeds in Rust, so
    // `Content-Length: +5` was accepted — a framing mis-parse two proxies
    // can disagree on (request smuggling primitive). Digits-only now.
    expect_reject(b"POST / HTTP/1.1\r\nContent-Length: +5\r\n\r\nhello", "plus-signed length");
    expect_reject(b"POST / HTTP/1.1\r\nContent-Length: 0x5\r\n\r\nhello", "hex length");
}

#[test]
fn header_name_trailing_space() {
    // Original failure: header names were trimmed, so `Content-Length : 5`
    // matched `content-length` here while standards-following peers treat
    // it as an unknown header — classic smuggling split. Now rejected.
    expect_reject(b"POST / HTTP/1.1\r\nContent-Length : 5\r\n\r\nhello", "spaced header name");
}

#[test]
fn too_many_headers() {
    // Original failure: no header-count cap — a few MB of tiny headers
    // ate a pool worker's memory and time. MAX_HEADERS now bounds it.
    let mut req = String::from("GET / HTTP/1.1\r\n");
    for i in 0..200 {
        req.push_str(&format!("X-Bomb-{i}: x\r\n"));
    }
    req.push_str("\r\n");
    match parse(req.as_bytes()) {
        Err(HttpError::TooLarge(_)) => {}
        other => panic!("header bomb must be TooLarge, got {other:?}"),
    }
}

#[test]
fn te_and_cl_smuggling() {
    // Transfer-Encoding alongside Content-Length is the canonical
    // request-smuggling vector (RFC 9112 §6.3); must die, not pick one.
    expect_reject(
        b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 3\r\n\r\n3\r\nabc\r\n0\r\n\r\n",
        "TE+CL",
    );
}

#[test]
fn chunk_size_overflow() {
    // Original failure class: a chunk-size line like `ffffffffffffffff1`
    // overflows usize if parsed unchecked. checked_mul/checked_add turn
    // it into BadChunk.
    let req = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nffffffffffffffff1\r\nx";
    match parse(req) {
        Err(HttpError::BadChunk(_)) => {}
        other => panic!("overflowing chunk size must be BadChunk, got {other:?}"),
    }
}

#[test]
fn chunked_total_over_cap() {
    // Chunked framing carries no up-front length, so the body cap must be
    // enforced on the *running decoded total*, before buffering the data.
    let mut req = String::from("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n");
    // 2 × 4096-byte chunks against a 4096 cap.
    for _ in 0..2 {
        req.push_str("1000\r\n");
        req.push_str(&"x".repeat(0x1000));
        req.push_str("\r\n");
    }
    req.push_str("0\r\n\r\n");
    match parse(req.as_bytes()) {
        Err(HttpError::TooLarge(_)) => {}
        other => panic!("oversized chunked body must be TooLarge, got {other:?}"),
    }
}

// ---- engine/spec.rs + net/wire.rs ------------------------------------------

#[test]
fn variant_model_name_unbounded() {
    // Original failure: parse_wire accepted arbitrary-length, arbitrary-
    // byte model names; a 1 MB name became a key in the serving catalog
    // lookup and echoed into logs/metrics labels. Now capped and
    // charset-restricted.
    let huge = "m".repeat(1 << 20) + "|fp32";
    assert!(VariantKey::parse_wire(&huge).is_err());
    assert!(VariantKey::parse_wire("a b|fp32").is_err());
    assert!(VariantKey::parse_wire("a\"b|fp32").is_err());
    // The longest legal name still parses.
    let max = "m".repeat(64) + "|fp32";
    assert!(VariantKey::parse_wire(&max).is_ok());
}

#[test]
fn wire_preamble_huge_number() {
    // Original failure class: attacker-chosen dims reach Shape::numel's
    // unchecked product — 2^33 × 2^33 overflows usize and panics the
    // worker. parse_shape's checked arithmetic turns it into an error.
    let head = r#"{"variant":"m|fp32","id":1,"shape":[8589934592,8589934592]}"#;
    let mut body = Vec::new();
    body.extend_from_slice(&(head.len() as u32).to_le_bytes());
    body.extend_from_slice(head.as_bytes());
    assert!(wire::decode_infer_request(&body).is_err());
    assert!(wire::decode_infer_response(&body).is_err());
    // A preamble length claiming more bytes than the body holds.
    assert!(wire::decode_infer_request(&[0xFF, 0xFF, 0xFF, 0x7F, b'{']).is_err());
}

// ---- artifact/ -------------------------------------------------------------

/// A packed baseline the corruption cases below start from.
fn packed() -> Vec<u8> {
    artifact::pack_model(
        &demo_model("regress"),
        PackOptions { calib_size: 4, ..PackOptions::default() },
    )
    .unwrap()
}

#[test]
fn artifact_header_shorter_than_fixed_frame() {
    // The loader indexes bytes[6..14] for the manifest length and CRC; a
    // file shorter than the fixed header must be a typed Truncated error
    // before any of those reads, for every prefix length including zero.
    let art = packed();
    for take in [0usize, 1, 5, 6, 9, 13] {
        let err = ArtifactEngine::from_bytes(&art[..take])
            .map(|_| ())
            .expect_err("a header prefix must never load");
        assert!(
            matches!(err, ArtifactError::Truncated { .. }),
            "{take}-byte prefix must be Truncated, got {err:?}"
        );
    }
    // Wrong magic with plausible framing behind it dies on BadMagic, not
    // on whatever the rest of the bytes happen to decode as.
    let mut bad = art.clone();
    bad[0] ^= 0x20;
    assert!(matches!(
        ArtifactEngine::from_bytes(&bad),
        Err(ArtifactError::BadMagic)
    ));
}

#[test]
fn artifact_manifest_len_claims_4gib() {
    // The u32 manifest-length field is attacker-controlled; a value near
    // u32::MAX must be rejected by the MAX_MANIFEST_BYTES cap before any
    // slice or allocation is sized from it.
    let mut art = packed();
    art[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(
        ArtifactEngine::from_bytes(&art),
        Err(ArtifactError::ManifestTooLarge { .. })
    ));
    // Just over the real manifest but under the cap: Truncated, computed
    // with overflow-safe arithmetic.
    let mut art = packed();
    let claim = (art.len() as u32).saturating_add(1);
    art[6..10].copy_from_slice(&claim.to_le_bytes());
    assert!(matches!(
        ArtifactEngine::from_bytes(&art),
        Err(ArtifactError::Truncated { .. })
    ));
}

#[test]
fn artifact_payload_bit_flip_is_checksum_mismatch() {
    // One flipped bit in the last payload section must surface as that
    // section's ChecksumMismatch — the CRC wall, not a downstream decode
    // error from poisoned tensor bytes.
    let mut art = packed();
    let last = art.len() - 1;
    art[last] ^= 0x01;
    assert!(matches!(
        ArtifactEngine::from_bytes(&art),
        Err(ArtifactError::ChecksumMismatch { .. })
    ));
    // The inspector (`pdq inspect`'s engine) agrees — same wall, typed
    // error, nonzero exit.
    assert!(artifact::inspect_bytes(&art).is_err());
}

#[test]
fn artifact_manifest_validate_extreme_payload_lengths() {
    // validate() compares the declared section layout against the actual
    // payload length; both extremes (empty and usize::MAX) must return
    // typed errors without overflow or panic.
    let art = packed();
    let report = artifact::inspect_bytes(&art).unwrap();
    assert!(matches!(
        report.manifest.validate(0),
        Err(ArtifactError::Truncated { .. })
    ));
    assert!(matches!(
        report.manifest.validate(usize::MAX),
        Err(ArtifactError::Truncated { .. })
    ));
    // The true length still validates.
    assert!(report.manifest.validate(report.payload_len).is_ok());
}

// ---- obs/slo.rs + coordinator/autopilot.rs ---------------------------------

#[test]
fn slo_query_plus_and_dot_prefixed_quantiles() {
    // Original failure class (the Content-Length lesson resurfacing):
    // `"+0.5".parse::<f64>()` and `".5".parse::<f64>()` both succeed in
    // Rust, so `q=+0.5` and `q=.5` parsed to values their own canonical
    // render spells differently — a render → parse round-trip drift the
    // fuzz oracle caught. Both spellings are now rejected up front.
    use pdq::obs::slo::SloQuery;
    assert!(SloQuery::parse("q=+0.5").is_err());
    assert!(SloQuery::parse("q=.5").is_err());
    // NaN/inf parse as f64 too; they must die before reaching the
    // quantile comparisons, which NaN would silently fall through.
    assert!(SloQuery::parse("q=nan").is_err());
    assert!(SloQuery::parse("q=inf").is_err());
    // The plain spelling still works and round-trips.
    let q = SloQuery::parse("q=0.5").unwrap();
    assert_eq!(SloQuery::parse(&q.render()).unwrap(), q);
}

#[test]
fn slo_query_zero_budget_and_truncated_escape() {
    use pdq::obs::slo::SloQuery;
    // budget_us=0 would make every burn computation divide by zero; it
    // must be a parse error, not a ledger full of inf.
    assert!(SloQuery::parse("budget_us=0").is_err());
    // A truncated percent escape at end-of-value indexed past the buffer
    // in the pre-hardening decoder. Typed error now, at every cut point.
    assert!(SloQuery::parse("variant=m%7").is_err());
    assert!(SloQuery::parse("variant=m%").is_err());
    // Control bytes smuggled through valid escapes (%0A = newline) would
    // corrupt the Prometheus exposition format's label values.
    assert!(SloQuery::parse("variant=m%0Afake_metric%201").is_err());
    // Duplicate budgets: two sources of truth for the denominator.
    assert!(SloQuery::parse("budget_us=1000&budget_us=2000").is_err());
}

#[test]
fn autopilot_spec_nan_step_and_overflowing_range() {
    use pdq::coordinator::autopilot::AutopilotConfig;
    // `"NaN".parse::<f64>()` succeeds; a NaN step survives every clamp
    // (NaN comparisons are all false) and turns the bounded retune ladder
    // into `depth × NaN → 0`. The digits-and-dot-only grammar kills it.
    assert!(AutopilotConfig::parse("step=NaN", 50_000).is_err());
    assert!(AutopilotConfig::parse("step=-0.25", 50_000).is_err());
    // An 18446744073709551616-shaped range bound overflows u64::from_str;
    // the strict parser reports it instead of wrapping.
    assert!(AutopilotConfig::parse("depth=1..18446744073709551616", 50_000).is_err());
    // Zero budget must be rejected even with an empty spec — the budget
    // arrives from a different flag than the spec and was once unchecked.
    assert!(AutopilotConfig::parse("", 0).is_err());
    // Duplicate keys: last-wins would make flag order change the control
    // law silently.
    assert!(AutopilotConfig::parse("dwell=2,dwell=3", 50_000).is_err());
    // The canonical render of the defaults still round-trips.
    let cfg = AutopilotConfig::parse("", 50_000).unwrap();
    assert_eq!(AutopilotConfig::parse(&cfg.render(), 50_000).unwrap(), cfg);
}

#[test]
fn artifact_nonzero_header_padding_rejected() {
    // The alignment pad between manifest and payload must be all zeros;
    // a byte smuggled into it changes file identity without touching any
    // CRC-covered region, so the loader pins it explicitly.
    let mut art = packed();
    let report = artifact::inspect_bytes(&art).unwrap();
    let pad_start = 14 + report.manifest_len;
    let payload_start = art.len() - report.payload_len;
    if pad_start < payload_start {
        art[pad_start] = 0xAA;
        let err = ArtifactEngine::from_bytes(&art)
            .map(|_| ())
            .expect_err("dirty padding must never load");
        match err {
            ArtifactError::BadManifest(why) => assert!(why.contains("padding")),
            other => panic!("dirty padding must be BadManifest, got {other:?}"),
        }
    }
}
