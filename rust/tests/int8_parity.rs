//! Int8-engine parity suite.
//!
//! Three layers of guarantees, strongest first:
//!
//! 1. **Kernel bit-exactness** — the fast int8 kernels
//!    (`pdq::cmsis::fast`, im2col + blocked GEMM + fused requant epilogue)
//!    must equal the naive scalar CMSIS ports *exactly* (integer equality)
//!    across randomized shapes, stride ∈ {1, 2}, pad ∈ {0, same} and both
//!    requant granularities.
//! 2. **Engine bit-exactness** — `Int8Executor::run_q` (arena, fused) must
//!    equal `Int8Executor::run_naive` (fresh tensors, scalar kernels,
//!    separate requantize sweep) exactly — values *and* grids — across
//!    modes × weight granularities × γ, including reused worker arenas.
//! 3. **Numeric fidelity** — dequantized int8 outputs track the f32
//!    emulator's `run_reference` (and fp32) within a bounded relative
//!    error: the engines quantize weights differently (symmetric int8 vs
//!    fake-quant), so equality is not expected, closeness is.
//!
//! Plus the §3 memory claim, enforced rather than asserted-by-docs: after a
//! static or PDQ pass the arena has never allocated the wide i32 buffer.

use std::sync::Arc;

use pdq::cmsis::fast;
use pdq::cmsis::{convolve_s8, dwconv_s8, fully_connected_s8, Requant};
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{float_exec, Graph, Int8Executor, QuantMode};
use pdq::quant::Granularity;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::check::Checker;
use pdq::util::Pcg32;

fn rand_i8(rng: &mut Pcg32, n: usize, lo: i64, hi: i64) -> Vec<i8> {
    (0..n).map(|_| rng.int_range(lo, hi) as i8).collect()
}

/// A random requant spec at either granularity, with a plausible offset.
fn rand_requant(rng: &mut Pcg32, channels: usize) -> Requant {
    let offset = rng.int_range(-20, 20) as i32;
    if rng.uniform() < 0.5 {
        Requant::per_tensor(2f64.powf(rng.uniform_range(-10.0, 0.0) as f64), offset)
    } else {
        let scales: Vec<f64> =
            (0..channels).map(|_| 2f64.powf(rng.uniform_range(-10.0, 0.0) as f64)).collect();
        Requant::per_channel(&scales, offset)
    }
}

#[test]
fn conv_fast_fused_exactly_matches_naive() {
    Checker::new(0x1817, 60).check("fast conv == convolve_s8", |rng| {
        let h = rng.int_range(3, 12) as usize;
        let w = rng.int_range(3, 12) as usize;
        let cin = rng.int_range(1, 7) as usize;
        let cout = rng.int_range(1, 9) as usize;
        let k = *rng.choice(&[1usize, 3, 5]);
        let stride = *rng.choice(&[1usize, 2]);
        let pad = *rng.choice(&[0usize, k / 2]);
        let geom = ConvGeom::new(k, k, stride, pad);
        let x = Tensor::from_vec(Shape::hwc(h, w, cin), rand_i8(rng, h * w * cin, -128, 127));
        let kt = Tensor::from_vec(
            Shape::ohwi(cout, k, k, cin),
            rand_i8(rng, cout * k * k * cin, -127, 127),
        );
        let bias: Vec<i32> = (0..cout).map(|_| rng.int_range(-3000, 3000) as i32).collect();
        let off = rng.int_range(-128, 128) as i32;
        let rq = rand_requant(rng, cout);
        let want = convolve_s8(&x, &kt, &bias, off, &rq, &geom);
        let mut cols = Vec::new();
        let mut got = vec![0i8; want.numel()];
        fast::convolve_s8_fast(&x, &kt, &bias, off, &geom, &mut cols, &mut got, fast::requant_epi(&rq));
        if got != *want.data() {
            return Err(format!(
                "conv mismatch h{h} w{w} cin{cin} cout{cout} k{k} s{stride} p{pad} off{off}"
            ));
        }
        Ok(())
    });
}

#[test]
fn dwconv_fast_fused_exactly_matches_naive() {
    Checker::new(0x1818, 60).check("fast dwconv == dwconv_s8", |rng| {
        let h = rng.int_range(3, 12) as usize;
        let w = rng.int_range(3, 12) as usize;
        let c = rng.int_range(1, 9) as usize;
        let k = *rng.choice(&[1usize, 3]);
        let stride = *rng.choice(&[1usize, 2]);
        let pad = *rng.choice(&[0usize, k / 2]);
        let geom = ConvGeom::new(k, k, stride, pad);
        let x = Tensor::from_vec(Shape::hwc(h, w, c), rand_i8(rng, h * w * c, -128, 127));
        let kt = Tensor::from_vec(Shape::new(&[c, k, k]), rand_i8(rng, c * k * k, -127, 127));
        let bias: Vec<i32> = (0..c).map(|_| rng.int_range(-3000, 3000) as i32).collect();
        let off = rng.int_range(-128, 128) as i32;
        let rq = rand_requant(rng, c);
        let want = dwconv_s8(&x, &kt, &bias, off, &rq, &geom);
        let mut wt = Vec::new();
        let mut acc_row = Vec::new();
        let mut got = vec![0i8; want.numel()];
        fast::dwconv_s8_fast(&x, &kt, &bias, off, &geom, &mut wt, &mut acc_row, &mut got, fast::requant_epi(&rq));
        if got != *want.data() {
            return Err(format!("dwconv mismatch h{h} w{w} c{c} k{k} s{stride} p{pad} off{off}"));
        }
        Ok(())
    });
}

#[test]
fn fc_fast_fused_exactly_matches_naive() {
    Checker::new(0x1819, 80).check("fast fc == fully_connected_s8", |rng| {
        let d = rng.int_range(1, 200) as usize;
        let h = rng.int_range(1, 32) as usize;
        let x = rand_i8(rng, d, -128, 127);
        let wt = Tensor::from_vec(Shape::new(&[h, d]), rand_i8(rng, h * d, -127, 127));
        let bias: Vec<i32> = (0..h).map(|_| rng.int_range(-5000, 5000) as i32).collect();
        let off = rng.int_range(-128, 128) as i32;
        let rq = rand_requant(rng, h);
        let want = fully_connected_s8(&x, &wt, &bias, off, &rq);
        let sums = fast::weight_row_sums(&wt);
        let mut got = vec![0i8; h];
        fast::fully_connected_s8_fast(&x, &wt, &bias, &sums, off, &mut got, fast::requant_epi(&rq));
        if got != want {
            return Err(format!("fc mismatch h{h} d{d} off{off}"));
        }
        Ok(())
    });
}

// ---- executor-level parity -------------------------------------------------

/// A residual net exercising every lowered op: conv (strided + same),
/// dwconv, residual add, relu/relu6, maxpool, gap, linear.
fn residual_net(rng: &mut Pcg32) -> Arc<Graph> {
    let mut g = Graph::new(Shape::hwc(16, 16, 3));
    let x = g.input();
    let w1: Vec<f32> = (0..8 * 9 * 3).map(|_| rng.normal_ms(0.0, 0.25)).collect();
    let c1 = g.conv(
        x,
        Tensor::from_vec(Shape::ohwi(8, 3, 3, 3), w1),
        vec![0.05; 8],
        ConvGeom::same(3, 1),
    );
    let r1 = g.relu(c1);
    let wd: Vec<f32> = (0..8 * 9).map(|_| rng.normal_ms(0.1, 0.3)).collect();
    let d1 = g.dwconv(
        r1,
        Tensor::from_vec(Shape::new(&[8, 3, 3]), wd),
        vec![0.02; 8],
        ConvGeom::same(3, 1),
    );
    let a = g.add(d1, r1);
    let r2 = g.relu6(a);
    let m = g.maxpool(r2, 2, 2);
    let w2: Vec<f32> = (0..12 * 9 * 8).map(|_| rng.normal_ms(0.0, 0.15)).collect();
    let c2 = g.conv(
        m,
        Tensor::from_vec(Shape::ohwi(12, 3, 3, 8), w2),
        vec![-0.03; 12],
        ConvGeom::same(3, 2),
    );
    let r3 = g.relu(c2);
    let p = g.global_avg_pool(r3);
    let wl: Vec<f32> = (0..5 * 12).map(|_| rng.normal_ms(0.0, 0.4)).collect();
    let l = g.linear(p, Tensor::from_vec(Shape::new(&[5, 12]), wl), vec![0.1; 5]);
    g.mark_output(l);
    Arc::new(g)
}

fn rand_image(rng: &mut Pcg32) -> Tensor<f32> {
    let data: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.uniform()).collect();
    Tensor::from_vec(Shape::hwc(16, 16, 3), data)
}

fn lowered(
    g: &Arc<Graph>,
    mode: QuantMode,
    weight_gran: Granularity,
    gamma: usize,
    calib: &[Tensor<f32>],
) -> (QuantExecutor, Int8Executor) {
    let mut ex = QuantExecutor::new(
        Arc::clone(g),
        QuantSettings { mode, gamma, granularity: Granularity::PerTensor, ..Default::default() },
    );
    ex.calibrate(calib);
    let int8 = Int8Executor::lower(&ex, weight_gran).expect("lowering succeeds");
    (ex, int8)
}

#[test]
fn fast_engine_bit_exact_vs_naive_engine() {
    let mut rng = Pcg32::new(0x181A);
    let g = residual_net(&mut rng);
    let calib: Vec<Tensor<f32>> = (0..6).map(|_| rand_image(&mut rng)).collect();
    let imgs: Vec<Tensor<f32>> = (0..3).map(|_| rand_image(&mut rng)).collect();
    for gamma in [1usize, 2, 4] {
        for weight_gran in [Granularity::PerTensor, Granularity::PerChannel] {
            for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
                let (_, int8) = lowered(&g, mode, weight_gran, gamma, &calib);
                for (i, img) in imgs.iter().enumerate() {
                    let naive = int8.run_naive(img);
                    let fast = int8.run_q(img).expect("run_q");
                    assert_eq!(naive.len(), fast.len());
                    for (j, ((tn, qn), (tf, qf))) in naive.iter().zip(fast.iter()).enumerate() {
                        assert_eq!(
                            qn, qf,
                            "{mode:?}/{weight_gran:?} γ={gamma} img{i} out{j}: grid mismatch"
                        );
                        assert_eq!(
                            tn.data(),
                            tf.data(),
                            "{mode:?}/{weight_gran:?} γ={gamma} img{i} out{j}: values differ"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn rung_engines_bit_exact_vs_scalar_oracle() {
    let mut rng = Pcg32::new(0x181F);
    let g = residual_net(&mut rng);
    let calib: Vec<Tensor<f32>> = (0..6).map(|_| rand_image(&mut rng)).collect();
    let imgs: Vec<Tensor<f32>> = (0..3).map(|_| rand_image(&mut rng)).collect();
    for weight_gran in [Granularity::PerTensor, Granularity::PerChannel] {
        for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
            let (_, int8) = lowered(&g, mode, weight_gran, 1, &calib);
            for bits in [4u32, 2] {
                let rung = int8.rung(bits).expect("rung derivation");
                assert_eq!(rung.bits(), bits);
                for (i, img) in imgs.iter().enumerate() {
                    // The oracle materializes the truncated weights and runs
                    // the naive scalar kernels; the fast engine applies the
                    // same shift inline at the weight load. Integer equality
                    // across values AND grids, like the 8-bit suite above.
                    let naive = rung.run_naive(img);
                    let fast = rung.run_q(img).expect("run_q");
                    assert_eq!(naive.len(), fast.len());
                    for (j, ((tn, qn), (tf, qf))) in naive.iter().zip(fast.iter()).enumerate() {
                        assert_eq!(
                            qn, qf,
                            "{mode:?}/{weight_gran:?} b{bits} img{i} out{j}: grid mismatch"
                        );
                        assert_eq!(
                            tn.data(),
                            tf.data(),
                            "{mode:?}/{weight_gran:?} b{bits} img{i} out{j}: values differ"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn rung8_is_bit_identical_to_the_base_program() {
    let mut rng = Pcg32::new(0x1820);
    let g = residual_net(&mut rng);
    let calib: Vec<Tensor<f32>> = (0..6).map(|_| rand_image(&mut rng)).collect();
    let imgs: Vec<Tensor<f32>> = (0..3).map(|_| rand_image(&mut rng)).collect();
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        let (_, int8) = lowered(&g, mode, Granularity::PerChannel, 1, &calib);
        let r8 = int8.rung(8).expect("rung 8");
        for (i, img) in imgs.iter().enumerate() {
            let base = int8.run_q(img).expect("base run");
            let rung = r8.run_q(img).expect("rung run");
            for (j, ((tb, qb), (tr, qr))) in base.iter().zip(rung.iter()).enumerate() {
                assert_eq!(qb, qr, "{mode:?} img{i} out{j}: rung(8) changed the grid");
                assert_eq!(
                    tb.data(),
                    tr.data(),
                    "{mode:?} img{i} out{j}: rung(8) must be bit-identical"
                );
            }
        }
    }
    // Rungs only derive from the 8-bit base, and only at 8/4/2.
    let (_, int8) = lowered(&g, QuantMode::Static, Granularity::PerTensor, 1, &calib);
    let r4 = int8.rung(4).expect("rung 4");
    assert!(r4.rung(2).is_err(), "re-deriving from a derived rung must refuse");
    assert!(int8.rung(3).is_err(), "bit-width 3 is not on the ladder");
    assert!(int8.rung(0).is_err(), "bit-width 0 is not on the ladder");
}

#[test]
fn rungs_preserve_the_static_memory_claim() {
    let mut rng = Pcg32::new(0x1821);
    let g = residual_net(&mut rng);
    let calib: Vec<Tensor<f32>> = (0..6).map(|_| rand_image(&mut rng)).collect();
    let img = rand_image(&mut rng);
    for mode in [QuantMode::Static, QuantMode::Probabilistic] {
        for bits in [4u32, 2] {
            let (_, int8) = lowered(&g, mode, Granularity::PerTensor, 1, &calib);
            let rung = int8.rung(bits).expect("rung");
            let mut arena = rung.make_arena();
            rung.run_q_with_arena(&img, &mut arena).expect("run");
            assert_eq!(
                arena.wide_capacity_elems(),
                0,
                "{mode:?} b{bits}: degraded rungs must keep the O(1) memory claim"
            );
        }
    }
}

#[test]
fn static_and_pdq_never_allocate_the_wide_buffer() {
    let mut rng = Pcg32::new(0x181B);
    let g = residual_net(&mut rng);
    let calib: Vec<Tensor<f32>> = (0..6).map(|_| rand_image(&mut rng)).collect();
    let img = rand_image(&mut rng);
    for mode in [QuantMode::Static, QuantMode::Probabilistic] {
        let (_, int8) = lowered(&g, mode, Granularity::PerTensor, 1, &calib);
        let mut arena = int8.make_arena();
        int8.run_q_with_arena(&img, &mut arena).expect("run");
        int8.run_q_with_arena(&img, &mut arena).expect("run");
        assert_eq!(
            arena.wide_capacity_elems(),
            0,
            "{mode:?}: the i32 accumulator tensor must never materialize (O(1) memory claim)"
        );
    }
    // Dynamic, by the §3 argument, must pay it.
    let (_, int8) = lowered(&g, QuantMode::Dynamic, Granularity::PerTensor, 1, &calib);
    let mut arena = int8.make_arena();
    int8.run_q_with_arena(&img, &mut arena).expect("run");
    assert!(
        arena.wide_capacity_elems() > 0,
        "dynamic mode buffers the wide output by definition"
    );
}

#[test]
fn worker_arena_reuse_is_deterministic() {
    let mut rng = Pcg32::new(0x181C);
    let g = residual_net(&mut rng);
    let calib: Vec<Tensor<f32>> = (0..6).map(|_| rand_image(&mut rng)).collect();
    let img = rand_image(&mut rng);
    let other = rand_image(&mut rng);
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        let (_, int8) = lowered(&g, mode, Granularity::PerTensor, 1, &calib);
        let mut arena = int8.make_arena();
        let a = int8.run_q_with_arena(&img, &mut arena).expect("run");
        let _ = int8.run_q_with_arena(&other, &mut arena).expect("run");
        let b = int8.run_q_with_arena(&img, &mut arena).expect("run");
        assert_eq!(a[0].0.data(), b[0].0.data(), "{mode:?}: arena reuse leaked state");
        assert_eq!(a[0].1, b[0].1, "{mode:?}: arena reuse changed the grid");
        // The internal-arena path agrees with the worker path.
        let c = int8.run_q(&img).expect("run_q");
        assert_eq!(a[0].0.data(), c[0].0.data(), "{mode:?}: run_q != run_q_with_arena");
    }
}

#[test]
fn int8_outputs_track_the_f32_emulator() {
    let mut rng = Pcg32::new(0x181D);
    let g = residual_net(&mut rng);
    let calib: Vec<Tensor<f32>> = (0..8).map(|_| rand_image(&mut rng)).collect();
    let img = rand_image(&mut rng);
    let fp = float_exec::run(&g, &img)[0].data().to_vec();
    for weight_gran in [Granularity::PerTensor, Granularity::PerChannel] {
        for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
            let (ex, int8) = lowered(&g, mode, weight_gran, 1, &calib);
            let reference = ex.run_reference(&img)[0].data().to_vec();
            let deq = int8.run(&img).expect("run")[0].data().to_vec();
            let rel = |a: &[f32], b: &[f32]| -> f32 {
                let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                let den: f32 = b.iter().map(|v| v * v).sum::<f32>().max(1e-9);
                (num / den).sqrt()
            };
            let e_ref = rel(&deq, &reference);
            let e_fp = rel(&deq, &fp);
            assert!(
                e_ref < 0.4,
                "{mode:?}/{weight_gran:?}: int8 vs run_reference rel err {e_ref}\nint8={deq:?}\nref={reference:?}"
            );
            assert!(
                e_fp < 0.4,
                "{mode:?}/{weight_gran:?}: int8 vs fp32 rel err {e_fp}\nint8={deq:?}\nfp={fp:?}"
            );
        }
    }
}

#[test]
fn lowering_rejects_unsupported_configs() {
    let mut rng = Pcg32::new(0x181E);
    let g = residual_net(&mut rng);
    // Uncalibrated static/PDQ must not lower; dynamic lowers fine.
    let ex = QuantExecutor::new(
        Arc::clone(&g),
        QuantSettings { mode: QuantMode::Static, ..Default::default() },
    );
    assert!(Int8Executor::lower(&ex, Granularity::PerTensor).is_err());
    let exd = QuantExecutor::new(
        Arc::clone(&g),
        QuantSettings { mode: QuantMode::Dynamic, ..Default::default() },
    );
    assert!(Int8Executor::lower(&exd, Granularity::PerTensor).is_ok());
    // Per-channel *activation* grids are out of scope for the CMSIS path.
    let exc = QuantExecutor::new(
        Arc::clone(&g),
        QuantSettings {
            mode: QuantMode::Dynamic,
            granularity: Granularity::PerChannel,
            ..Default::default()
        },
    );
    assert!(Int8Executor::lower(&exc, Granularity::PerTensor).is_err());
}
