//! Bounded fuzz smoke — the CI face of `pdq::testing::fuzz`.
//!
//! Fixed seeds, fixed iteration budgets, plain `cargo test`: every
//! byte-level target gets ≥10k seeded cases and the int8 differential
//! targets get a kernel budget of the same size plus a handful of full
//! graph lowerings. Any panic or mis-parse fails the suite; the harness
//! prints `(seed, case, hex input)` so a failure can be replayed and
//! checked into `fuzz_regressions.rs` as a named case.
//!
//! Budgets are sized for release-mode CI (`cargo test --release`); in
//! debug they still finish, just slower.

use pdq::testing::fuzz;

const ITERS: u32 = 10_000;

#[test]
fn fuzz_http_request_parsing() {
    fuzz::run_bytes(0x5EED_0001, ITERS, fuzz::gen_http_request, fuzz::target_http_request);
}

#[test]
fn fuzz_wire_preamble_decoding() {
    fuzz::run_bytes(0x5EED_0002, ITERS, fuzz::gen_wire_body, fuzz::target_wire_preamble);
}

#[test]
fn fuzz_variant_key_wire_parsing() {
    fuzz::run_bytes(0x5EED_0003, ITERS, fuzz::gen_variant_wire, fuzz::target_variant_wire);
}

#[test]
fn fuzz_json_documents() {
    fuzz::run_bytes(0x5EED_0004, ITERS, fuzz::gen_json, fuzz::target_json);
}

#[test]
fn fuzz_boundary_shapes() {
    fuzz::run_bytes(0x5EED_0005, ITERS, fuzz::gen_shape_dims, fuzz::target_shape);
}

#[test]
fn fuzz_trace_header_parsing() {
    fuzz::run_bytes(0x5EED_0008, ITERS, fuzz::gen_trace_header, fuzz::target_trace_header);
}

#[test]
fn fuzz_artifact_manifest_json() {
    fuzz::run_bytes(0x5EED_0009, ITERS, fuzz::gen_manifest_json, fuzz::target_manifest_json);
}

#[test]
fn fuzz_artifact_payload_loading() {
    fuzz::run_bytes(0x5EED_000A, ITERS, fuzz::gen_artifact_payload, fuzz::target_artifact_payload);
}

#[test]
fn fuzz_slo_query_parsing() {
    fuzz::run_bytes(0x5EED_000B, ITERS, fuzz::gen_slo_query, fuzz::target_slo_query);
}

#[test]
fn fuzz_autopilot_config_grammar() {
    fuzz::run_bytes(0x5EED_000C, ITERS, fuzz::gen_autopilot_spec, fuzz::target_autopilot_config);
}

#[test]
fn fuzz_int8_kernels_differential() {
    fuzz::diff_int8_kernels(0x5EED_0006, ITERS);
}

#[test]
fn fuzz_int8_graphs_differential() {
    fuzz::diff_int8_graphs(0x5EED_0007, 8);
}
