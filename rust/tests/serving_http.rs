//! End-to-end socket tests for the network front door (no artifacts
//! needed — variants are built from small seeded in-test models).
//!
//! What is proven here:
//! 1. requests over real TCP come back **bit-identical** to direct
//!    `pdq::engine` session execution, concurrently, across fp32 /
//!    quant-emulation / true-int8 variants;
//! 2. a depth-1 admission queue sheds deterministically with 429 +
//!    `Retry-After`, the sheds land in `Metrics::rejected`, and the server
//!    still drains cleanly afterwards;
//! 3. graceful drain answers every accepted request before workers join;
//! 4. `/healthz`, `/v1/variants` and `/metrics` (JSON + Prometheus) serve
//!    over the same listener, and the load generator survives a full
//!    closed-loop run with zero dropped responses.

use std::sync::Arc;
use std::time::Duration;

use pdq::coordinator::batcher::BatchPolicy;
use pdq::coordinator::{Server, ServerConfig};
use pdq::engine::{Engine, Int8Engine, QuantEngine, VariantKey, VariantSpec};
use pdq::net::loadgen::{self, LoadMode, LoadgenConfig};
use pdq::net::wire::{Client, InferOutcome};
use pdq::net::{FrontDoor, FrontDoorConfig};
use pdq::nn::int8_exec::Int8Executor;
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{Graph, QuantMode};
use pdq::quant::Granularity;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::json::Json;
use pdq::util::Pcg32;

const HW: usize = 8;
const CIN: usize = 2;

/// conv(2→4, 3x3) → relu → gap, input 8×8×2; weights seeded.
fn test_graph() -> Arc<Graph> {
    let mut rng = Pcg32::new(0xF00D);
    let mut g = Graph::new(Shape::hwc(HW, HW, CIN));
    let x = g.input();
    let w: Vec<f32> = (0..4 * 9 * CIN).map(|_| rng.normal_ms(0.0, 0.3)).collect();
    let c = g.conv(
        x,
        Tensor::from_vec(Shape::ohwi(4, 3, 3, CIN), w),
        vec![0.05, -0.05, 0.0, 0.1],
        ConvGeom::same(3, 1),
    );
    let r = g.relu(c);
    let p = g.global_avg_pool(r);
    g.mark_output(p);
    Arc::new(g)
}

fn calib_images() -> Vec<Tensor<f32>> {
    let mut rng = Pcg32::new(0xCA11);
    (0..8)
        .map(|_| {
            let d: Vec<f32> = (0..HW * HW * CIN).map(|_| rng.uniform()).collect();
            Tensor::from_vec(Shape::hwc(HW, HW, CIN), d)
        })
        .collect()
}

/// Deterministic build, so constructing it twice (one copy moves into the
/// server, one stays local as the oracle) yields bit-identical engines.
fn build_variant(spec: &VariantSpec) -> (VariantKey, Arc<dyn Engine>) {
    let key = VariantKey::new("t", *spec);
    let graph = test_graph();
    let engine: Arc<dyn Engine> = match *spec {
        VariantSpec::Fp32 => Arc::new(pdq::engine::FloatEngine::new(graph)),
        VariantSpec::FakeQuant { mode, gran } => {
            let mut ex = QuantExecutor::new(
                graph,
                QuantSettings { mode, granularity: gran, ..Default::default() },
            );
            ex.calibrate(&calib_images());
            Arc::new(QuantEngine::new(Arc::new(ex)))
        }
        VariantSpec::Int8 { mode, weight_gran, bits: _ } => {
            let mut ex = QuantExecutor::new(
                graph,
                QuantSettings { mode, granularity: Granularity::PerTensor, ..Default::default() },
            );
            ex.calibrate(&calib_images());
            Arc::new(Int8Engine::new(Arc::new(
                Int8Executor::lower(&ex, weight_gran).expect("lowering"),
            )))
        }
    };
    (key, engine)
}

fn test_modes() -> Vec<VariantSpec> {
    vec![
        VariantSpec::Fp32,
        VariantSpec::FakeQuant {
            mode: QuantMode::Probabilistic,
            gran: Granularity::PerTensor,
        },
        VariantSpec::Int8 {
            mode: QuantMode::Probabilistic,
            weight_gran: Granularity::PerTensor,
            bits: 8,
        },
    ]
}

fn start_front_door(config: ServerConfig) -> (FrontDoor, String) {
    let variants: Vec<(VariantKey, Arc<dyn Engine>)> =
        test_modes().iter().map(build_variant).collect();
    let server = Arc::new(Server::start(variants, config));
    let fd = FrontDoor::start(server, FrontDoorConfig::default()).expect("bind ephemeral port");
    let addr = fd.local_addr().to_string();
    (fd, addr)
}

fn bits(t: &Tensor<f32>) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

/// Acceptance test 1: concurrent socket inference across ≥2 variants
/// (including int8) is bit-identical to direct execution.
#[test]
fn socket_infer_bit_identical_to_direct_execution() {
    let (fd, addr) = start_front_door(ServerConfig::default());
    let images = calib_images();
    let mut joins = Vec::new();
    for (t, mode) in test_modes().into_iter().enumerate() {
        let addr = addr.clone();
        let images = images.clone();
        joins.push(std::thread::spawn(move || {
            // Local oracle copy of the same variant, executed exactly the
            // way the workers do (a compiled engine session).
            let (key, oracle) = build_variant(&mode);
            let mut session = oracle.compile().expect("oracle session");
            let mut client = Client::new(&addr);
            for (i, img) in images.iter().enumerate() {
                let id = (t * 100 + i) as u64;
                let got = match client.post_infer(&key, id, img).expect("transport") {
                    InferOutcome::Ok(resp) => resp,
                    InferOutcome::Rejected { .. } => panic!("unexpected shed (unbounded queue)"),
                    InferOutcome::Failed { status, error } => panic!("http {status}: {error}"),
                };
                assert_eq!(got.id, id);
                let want = session.run(img).expect("oracle run");
                assert_eq!(got.outputs.len(), want.len());
                for (g, w) in got.outputs.iter().zip(&want) {
                    assert_eq!(g.shape(), w.shape());
                    assert_eq!(bits(g), bits(w), "{} must be bit-identical", key.wire());
                }
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let metrics = fd.shutdown();
    assert_eq!(metrics.responses(), 3 * 8);
    assert_eq!(metrics.rejected(), 0);
}

/// Acceptance test 2: overload a depth-1 queue → deterministic 429s with a
/// retry hint, counted in `Metrics::rejected`, and a clean drain after.
#[test]
fn depth_one_overload_sheds_with_429_then_drains_clean() {
    let variants: Vec<(VariantKey, Arc<dyn Engine>)> =
        test_modes().iter().map(build_variant).collect();
    let server = Arc::new(Server::start(
        variants,
        ServerConfig { max_queue_depth: 1, ..Default::default() },
    ));
    let fd = FrontDoor::start(Arc::clone(&server), FrontDoorConfig::default()).unwrap();
    let addr = fd.local_addr().to_string();
    let key = VariantKey::new("t", VariantSpec::Fp32);
    let img = calib_images().remove(0);

    // Occupy the single slot from in-process: the permit is held, so every
    // HTTP request below MUST shed — no timing involved.
    let (rx_held, permit_held) = server.try_submit(key.clone(), 0, img.clone()).unwrap();
    let mut client = Client::new(&addr);
    let mut sheds = 0u64;
    for i in 0..5u64 {
        match client.post_infer(&key, 1 + i, &img).expect("transport") {
            InferOutcome::Rejected { retry_after_ms } => {
                sheds += 1;
                assert!(retry_after_ms >= 1, "Retry-After hint must be present");
            }
            other => panic!(
                "request {i} must be shed while the slot is held, got {}",
                match other {
                    InferOutcome::Ok(_) => "200".to_string(),
                    InferOutcome::Failed { status, .. } => format!("{status}"),
                    InferOutcome::Rejected { .. } => unreachable!(),
                }
            ),
        }
    }
    assert_eq!(sheds, 5);
    assert_eq!(server.metrics().shed(), 5, "sheds counted");
    assert_eq!(server.metrics().rejected(), 5, "sheds land in rejected()");

    // The raw 429 carries a Retry-After header too.
    let body = pdq::net::wire::encode_infer_request(&key, 99, &img);
    let parts = client
        .request("POST", "/v1/infer", pdq::net::wire::TENSOR_CONTENT_TYPE, &body)
        .unwrap();
    assert_eq!(parts.status, 429);
    assert!(parts.header("retry-after").is_some());

    // Release the slot: service recovers.
    rx_held.recv_timeout(Duration::from_secs(5)).unwrap();
    drop(permit_held);
    match client.post_infer(&key, 50, &img).unwrap() {
        InferOutcome::Ok(resp) => assert_eq!(resp.id, 50),
        _ => panic!("must serve again after the slot freed"),
    }

    // And the server still drains cleanly.
    let metrics = fd.shutdown();
    assert_eq!(metrics.responses(), 2, "held request + post-recovery request");
    assert_eq!(metrics.shed(), 6);
}

/// Graceful drain over the wire: requests queued inside the coordinator at
/// shutdown time are all answered before the workers join.
#[test]
fn drain_answers_every_queued_request() {
    let variants: Vec<(VariantKey, Arc<dyn Engine>)> =
        test_modes().iter().map(build_variant).collect();
    let server = Arc::new(Server::start(
        variants,
        ServerConfig {
            workers_per_variant: 1,
            policy: BatchPolicy { max_batch: 1, deadline: Duration::from_millis(1) },
            max_queue_depth: 0,
            ..Default::default()
        },
    ));
    let fd = FrontDoor::start(Arc::clone(&server), FrontDoorConfig::default()).unwrap();
    let key = VariantKey::new("t", VariantSpec::Fp32);
    let img = calib_images().remove(0);
    // Build a backlog through the coordinator directly (the front door's
    // conn pool would serialize HTTP submissions), then drain while queued.
    let rxs: Vec<_> =
        (0..48u64).map(|id| server.submit(key.clone(), id, img.clone()).unwrap()).collect();
    let metrics = fd.shutdown();
    for (id, rx) in rxs.into_iter().enumerate() {
        rx.recv_timeout(Duration::from_secs(5))
            .unwrap_or_else(|_| panic!("request {id} lost in drain"));
    }
    assert_eq!(metrics.responses(), 48);
}

#[test]
fn observability_endpoints_serve_json_and_prometheus() {
    let (fd, addr) = start_front_door(ServerConfig { max_queue_depth: 7, ..Default::default() });
    let mut client = Client::new(&addr);

    let health = client.get("/healthz").unwrap();
    assert_eq!(health.status, 200);
    let j = Json::parse(std::str::from_utf8(&health.body).unwrap()).unwrap();
    assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(j.get("variants").unwrap().as_usize(), Some(3));

    let vars = client.get("/v1/variants").unwrap();
    let j = Json::parse(std::str::from_utf8(&vars.body).unwrap()).unwrap();
    let list = j.get("variants").unwrap().as_arr().unwrap();
    assert_eq!(list.len(), 3);
    assert_eq!(j.get("max_queue_depth").unwrap().as_usize(), Some(7));
    let wires: Vec<&str> =
        list.iter().filter_map(|v| v.get("variant").and_then(|s| s.as_str())).collect();
    assert!(wires.contains(&"t|fp32"));
    assert!(wires.contains(&"t|int8-ours-t"));
    for v in list {
        assert_eq!(
            v.get("input_shape").unwrap().as_arr().unwrap().len(),
            3,
            "HWC input shape advertised"
        );
    }

    // One inference so latency metrics are non-empty.
    let key = VariantKey::new("t", VariantSpec::Fp32);
    let img = calib_images().remove(0);
    assert!(matches!(client.post_infer(&key, 1, &img).unwrap(), InferOutcome::Ok(_)));

    let m = client.get("/metrics").unwrap();
    assert_eq!(m.status, 200);
    let j = Json::parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
    assert_eq!(j.get("responses").unwrap().as_usize(), Some(1));
    assert!(j.get("in_flight").unwrap().get("t|fp32").is_some());

    let prom = client.get("/metrics?format=prometheus").unwrap();
    assert_eq!(prom.status, 200);
    assert_eq!(prom.header("content-type"), Some("text/plain; version=0.0.4"));
    let text = String::from_utf8(prom.body).unwrap();
    assert!(text.contains("pdq_responses_total 1"), "{text}");
    assert!(text.contains("# TYPE pdq_request_latency_us histogram"));
    assert!(text.contains("pdq_inflight{variant=\"t|int8-ours-t\"} 0"));

    // Error-path routing on the same connection.
    let missing = client.get("/nope").unwrap();
    assert_eq!(missing.status, 404);
    let wrong_method = client.get("/v1/infer").unwrap();
    assert_eq!(wrong_method.status, 405);
    let garbage = client.request("POST", "/v1/infer", "application/json", b"not a tensor").unwrap();
    assert_eq!(garbage.status, 400);
    let ghost = pdq::net::wire::encode_infer_request(
        &VariantKey::new("ghost", VariantSpec::Fp32),
        1,
        &img,
    );
    let unknown = client
        .request("POST", "/v1/infer", pdq::net::wire::TENSOR_CONTENT_TYPE, &ghost)
        .unwrap();
    assert_eq!(unknown.status, 404);
    // Shape mismatch is rejected at the boundary, not by a worker panic.
    let bad_shape = pdq::net::wire::encode_infer_request(
        &key,
        1,
        &Tensor::full(Shape::hwc(2, 2, 1), 1.0),
    );
    let bad = client
        .request("POST", "/v1/infer", pdq::net::wire::TENSOR_CONTENT_TYPE, &bad_shape)
        .unwrap();
    assert_eq!(bad.status, 400);

    fd.shutdown();
}

/// The load generator end to end: closed loop against a live front door,
/// zero dropped responses, and a well-formed `BENCH_serving.json`.
#[test]
fn loadgen_closed_loop_zero_drops() {
    let (fd, addr) = start_front_door(ServerConfig::default());
    let cfg = LoadgenConfig {
        target: addr,
        mode: LoadMode::Closed,
        concurrency: 3,
        duration: Duration::from_millis(600),
        ..Default::default()
    };
    let report = loadgen::run(&cfg).expect("loadgen run");
    assert!(report.total.sent > 0, "must have sent traffic");
    assert_eq!(report.total.dropped, 0, "every request must get an HTTP response");
    assert_eq!(report.total.failed, 0);
    assert_eq!(report.per_variant.len(), 3, "drives every advertised variant");
    assert!(report.per_variant.iter().all(|v| v.sent > 0));
    // Round-trip the report file.
    let path = std::env::temp_dir().join("pdq_bench_serving_test.json");
    report.save(path.to_str().unwrap()).unwrap();
    let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(back.get("schema").unwrap().as_str(), Some("pdq-serving-v2"));
    assert_eq!(
        back.get("aggregate").unwrap().get("dropped").unwrap().as_usize(),
        Some(0)
    );
    // Tracing is disarmed on this server: v2 reports that honestly.
    assert_eq!(back.get("aggregate").unwrap().get("traced").unwrap().as_usize(), Some(0));
    // The post-run stage snapshot from /metrics rode along.
    assert!(back.get("stages").is_some(), "stage attribution snapshot embedded");
    let _ = std::fs::remove_file(&path);
    let metrics = fd.shutdown();
    assert_eq!(metrics.responses() as u64, report.total.ok);
}

/// Hot-load a packed artifact into the serving zoo mid-run, drive it,
/// and unload it — all while closed-loop traffic hammers the pinned
/// model. Acceptance: zero drops across the load and unload, model-id
/// labels visible in both metrics formats, no leaked admission permits
/// (every in-flight gauge drains to zero), and the pinned model still
/// serves after the churn.
#[test]
fn hot_load_and_unload_under_sustained_traffic() {
    // Pack the second model up front: packing is the slow step, and doing
    // it first keeps the load/unload inside the loadgen window.
    let art = pdq::artifact::pack_model(
        &pdq::coordinator::calibrate::demo_model("zoo2"),
        pdq::artifact::PackOptions { calib_size: 4, ..Default::default() },
    )
    .expect("pack");

    let (fd, addr) = start_front_door(ServerConfig::default());
    let lg_addr = addr.clone();
    let lg = std::thread::spawn(move || {
        loadgen::run(&LoadgenConfig {
            target: lg_addr,
            mode: LoadMode::Closed,
            concurrency: 2,
            duration: Duration::from_millis(1500),
            models: vec!["t".into()],
            ..Default::default()
        })
        .expect("loadgen run")
    });

    let mut client = Client::new(&addr);
    let resp = client
        .request("POST", "/v1/models", "application/octet-stream", &art)
        .expect("hot-load transport");
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    assert_eq!(j.get("loaded").unwrap().as_str(), Some("zoo2"));

    // The zoo endpoint lists both models, the new one unpinned.
    let models = client.get("/v1/models").unwrap();
    let j = Json::parse(std::str::from_utf8(&models.body).unwrap()).unwrap();
    let list = j.get("models").unwrap().as_arr().unwrap();
    let pinned_of = |name: &str| {
        list.iter()
            .find(|m| m.get("model").and_then(|v| v.as_str()) == Some(name))
            .and_then(|m| m.get("pinned"))
            .and_then(|v| v.as_bool())
    };
    assert_eq!(pinned_of("t"), Some(true));
    assert_eq!(pinned_of("zoo2"), Some(false));

    // Drive the hot-loaded model while the background traffic runs.
    let zkey = VariantKey::new("zoo2", VariantSpec::Fp32);
    let zimg = Tensor::full(Shape::hwc(32, 32, 3), 0.5);
    for id in 0..4u64 {
        match client.post_infer(&zkey, id, &zimg).expect("transport") {
            InferOutcome::Ok(resp) => assert_eq!(resp.id, id),
            InferOutcome::Rejected { .. } => panic!("zoo2 shed while loaded"),
            InferOutcome::Failed { status, error } => {
                panic!("zoo2 must serve while loaded, got {status}: {error}")
            }
        }
    }

    // Model-id labels ride in both metrics formats.
    let m = client.get("/metrics").unwrap();
    let j = Json::parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
    assert!(j.get("in_flight").unwrap().get("zoo2|fp32").is_some());
    assert!(j.get("in_flight").unwrap().get("t|fp32").is_some());
    let prom = client.get("/metrics?format=prometheus").unwrap();
    let text = String::from_utf8(prom.body).unwrap();
    assert!(text.contains("pdq_inflight{variant=\"zoo2|fp32\"}"), "{text}");
    assert!(text.contains("pdq_inflight{variant=\"t|fp32\"}"), "{text}");

    // Unload: zoo2 traffic 404s afterwards, the pinned model is untouched.
    let del = client.request("DELETE", "/v1/models/zoo2", "application/json", b"").unwrap();
    assert_eq!(del.status, 200, "{}", String::from_utf8_lossy(&del.body));
    let body = pdq::net::wire::encode_infer_request(&zkey, 9, &zimg);
    let gone = client
        .request("POST", "/v1/infer", pdq::net::wire::TENSOR_CONTENT_TYPE, &body)
        .unwrap();
    assert_eq!(gone.status, 404, "unloaded model must be gone from the catalog");

    let report = lg.join().unwrap();
    assert!(report.total.sent > 0, "background traffic ran");
    assert_eq!(report.total.dropped, 0, "zero drops across hot-load and unload");
    assert_eq!(report.total.failed, 0);
    assert!(
        report.per_variant.iter().all(|v| v.wire.starts_with("t|")),
        "--models filter pinned traffic to model t"
    );

    // No leaked admission permits: every in-flight gauge drains to zero.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let m = client.get("/metrics").unwrap();
        let j = Json::parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        let drained = match j.get("in_flight").unwrap() {
            Json::Obj(map) => map.values().all(|v| v.as_usize() == Some(0)),
            _ => false,
        };
        if drained {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "admission permits leaked: {}",
            j.to_string_compact()
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    // The pinned model still serves after the zoo churn.
    let tkey = VariantKey::new("t", VariantSpec::Fp32);
    let timg = calib_images().remove(0);
    assert!(matches!(client.post_infer(&tkey, 777, &timg).unwrap(), InferOutcome::Ok(_)));
    fd.shutdown();
}

/// Open-loop discipline fires on schedule even when responses lag, and the
/// report's offered-vs-achieved bookkeeping holds together.
#[test]
fn loadgen_open_loop_respects_schedule() {
    let (fd, addr) = start_front_door(ServerConfig::default());
    let cfg = LoadgenConfig {
        target: addr,
        mode: LoadMode::Open { rps: 200.0 },
        concurrency: 2,
        duration: Duration::from_millis(500),
        ..Default::default()
    };
    let report = loadgen::run(&cfg).expect("loadgen run");
    // 200 rps × 0.5 s = 100 scheduled sends (the last slot may straddle
    // the deadline; allow slack for coarse schedulers).
    assert!(
        (80..=100).contains(&(report.total.sent as usize)),
        "open loop sent {} of ~100 scheduled",
        report.total.sent
    );
    assert_eq!(report.total.dropped, 0);
    let metrics = fd.shutdown();
    assert_eq!(metrics.responses() as u64, report.total.ok);
}
