//! Cross-layer integration tests. These require `make artifacts` (the
//! Makefile's `test` target guarantees the ordering).
//!
//! What is proven here:
//! 1. the Rust float engine reproduces the JAX model bit-for-bit-ish
//!    (golden fixtures exported by `aot.py`) — weights, layouts and op
//!    semantics all agree;
//! 2. the PJRT runtime loads every AOT HLO artifact and its outputs match
//!    the Rust float engine on the same inputs;
//! 3. the AOT estimator (L2 graph wrapping the L1 Pallas kernel) matches
//!    the Rust estimator — i.e. the paper's Eq. 10–12 agree across all
//!    three implementations (Pallas/jnp, PJRT, Rust).

use std::path::Path;
use std::sync::Arc;

use pdq::data::shapes;
use pdq::estimator::{conv as conv_est, WeightStats};
use pdq::models::zoo;
use pdq::nn::float_exec;
use pdq::nn::{QuantExecutor, QuantMode};
use pdq::quant::Granularity;
use pdq::runtime::Runtime;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::Pcg32;

fn artifacts_dir() -> &'static Path {
    // Box::leak (not PathBuf::leak) keeps the MSRV low.
    Box::leak(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").into_boxed_path())
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Golden parity: Rust float engine vs JAX outputs recorded at AOT time.
#[test]
fn rust_float_engine_matches_jax_goldens() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = zoo::load_manifest(artifacts_dir()).unwrap();
    let names = zoo::model_names(&manifest);
    assert_eq!(names.len(), 6, "expected the full zoo");
    for name in names {
        let model = zoo::load_model(artifacts_dir(), &manifest, &name).unwrap();
        let (seed, golden) = model.golden.clone().expect("golden fixture");
        let sample = shapes::generate(model.task, seed);
        let input = sample.image_f32();
        let outs = float_exec::run(&model.graph, &input);
        let flat: Vec<f32> = outs.iter().flat_map(|t| t.data().iter().copied()).collect();
        assert_eq!(flat.len(), golden.len(), "{name}: output arity");
        for (i, (&got, &want)) in flat.iter().zip(golden.iter()).enumerate() {
            assert!(
                (got - want).abs() <= 1e-3 + 1e-3 * want.abs(),
                "{name}[{i}]: rust {got} vs jax {want}"
            );
        }
        println!("golden parity OK: {name} ({} outputs)", flat.len());
    }
}

/// PJRT path: load each model's HLO, execute, compare to the float engine.
#[test]
fn pjrt_runtime_matches_float_engine() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = zoo::load_manifest(artifacts_dir()).unwrap();
    let rt = Runtime::cpu().unwrap();
    for name in zoo::model_names(&manifest) {
        let model = zoo::load_model(artifacts_dir(), &manifest, &name).unwrap();
        let exe = rt.load(model.hlo_path.as_ref().unwrap()).unwrap();
        let sample = shapes::generate(model.task, 424242);
        let input = sample.image_f32();
        let pjrt_out = exe.run_f32(&[&input]).unwrap();
        let flat_pjrt: Vec<f32> = pjrt_out.into_iter().flatten().collect();
        let rust_out = float_exec::run(&model.graph, &input);
        let flat_rust: Vec<f32> = rust_out.iter().flat_map(|t| t.data().iter().copied()).collect();
        assert_eq!(flat_pjrt.len(), flat_rust.len(), "{name}");
        // Tolerance note: XLA accumulates convs in f32 with fused reordering
        // while the Rust engine uses f64 accumulators; relu thresholds can
        // amplify the difference through depth. 3e-2 absolute on O(1)
        // outputs still catches any wiring/layout/weight mismatch.
        for (i, (&a, &b)) in flat_pjrt.iter().zip(flat_rust.iter()).enumerate() {
            assert!((a - b).abs() <= 3e-2 + 3e-2 * b.abs(), "{name}[{i}]: pjrt {a} vs rust {b}");
        }
        println!("pjrt parity OK: {name}");
    }
    assert_eq!(rt.cached_count(), 6);
}

/// Estimator parity: the AOT estimator HLO (L2 graph wrapping the L1
/// Pallas moments kernel) vs the Rust estimator.
#[test]
fn aot_estimator_matches_rust_estimator() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = zoo::load_manifest(artifacts_dir()).unwrap();
    let est_info = manifest.get("aot").unwrap().get("estimator").unwrap();
    let (h, w, c) = (
        est_info.get("h").unwrap().as_usize().unwrap(),
        est_info.get("w").unwrap().as_usize().unwrap(),
        est_info.get("c").unwrap().as_usize().unwrap(),
    );
    let k = est_info.get("k").unwrap().as_usize().unwrap();
    let stride = est_info.get("stride").unwrap().as_usize().unwrap();
    let pad = est_info.get("pad").unwrap().as_usize().unwrap();
    let gamma = est_info.get("gamma").unwrap().as_usize().unwrap();
    let hlo = artifacts_dir().join(est_info.get("hlo").unwrap().as_str().unwrap());

    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&hlo).unwrap();
    let mut rng = Pcg32::new(99);
    let data: Vec<f32> = (0..h * w * c).map(|_| rng.normal_ms(0.3, 0.8)).collect();
    let x = Tensor::from_vec(Shape::hwc(h, w, c), data);
    let (mu_w, var_w) = (0.07f32, 0.04f32);
    let out = exe.run_tensor_scalars(&x, &[mu_w, var_w]).unwrap();
    let aot_mean = out[0][0];
    let aot_var = out[0][1];
    let ws = WeightStats { mu: mu_w, var: var_w, mu_ch: vec![], var_ch: vec![], fan_in: c * k * k };
    let geom = ConvGeom::new(k, k, stride, pad);
    let rust_m = conv_est::estimate(&x, &ws, &geom, gamma);
    assert!(
        (aot_mean - rust_m.mean).abs() <= 1e-2 + 1e-3 * rust_m.mean.abs(),
        "mean: aot {aot_mean} vs rust {}",
        rust_m.mean
    );
    assert!(
        (aot_var - rust_m.var).abs() <= 1e-2 + 2e-3 * rust_m.var.abs(),
        "var: aot {aot_var} vs rust {}",
        rust_m.var
    );
    println!("estimator parity OK: mean {aot_mean} var {aot_var}");
}

/// End-to-end quantized accuracy sanity: the calibrated emulator must not
/// collapse on real trained models.
#[test]
fn quantized_models_keep_accuracy() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = zoo::load_manifest(artifacts_dir()).unwrap();
    let model = zoo::load_model(artifacts_dir(), &manifest, "micro_resnet").unwrap();
    let calib: Vec<Tensor<f32>> = shapes::dataset(pdq::data::Task::Cls, shapes::Split::Calib, 16)
        .iter()
        .map(|s| s.image_f32())
        .collect();
    let test = shapes::dataset(pdq::data::Task::Cls, shapes::Split::Test, 100);

    let fp_acc = accuracy(&model.graph, &test, None);
    assert!(fp_acc > 0.8, "fp32 accuracy {fp_acc} too low — training failed?");
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        let mut ex = QuantExecutor::new(
            Arc::clone(&model.graph),
            pdq::nn::quant_exec::QuantSettings {
                mode,
                granularity: Granularity::PerTensor,
                ..Default::default()
            },
        );
        ex.calibrate(&calib);
        let acc = accuracy_q(&ex, &test);
        println!("{}: acc {acc} (fp32 {fp_acc})", mode.label());
        assert!(
            acc > fp_acc - 0.15,
            "{}: quantized acc {acc} collapsed vs fp32 {fp_acc}",
            mode.label()
        );
    }
}

fn accuracy(graph: &pdq::nn::Graph, test: &[shapes::DataSample], _: Option<()>) -> f32 {
    let preds: Vec<usize> = test
        .iter()
        .map(|s| argmax(float_exec::run(graph, &s.image_f32())[0].data()))
        .collect();
    let labels: Vec<usize> = test.iter().map(|s| s.class_id).collect();
    pdq::eval::top1(&preds, &labels)
}

fn accuracy_q(ex: &QuantExecutor, test: &[shapes::DataSample]) -> f32 {
    let preds: Vec<usize> =
        test.iter().map(|s| argmax(ex.run(&s.image_f32()).unwrap()[0].data())).collect();
    let labels: Vec<usize> = test.iter().map(|s| s.class_id).collect();
    pdq::eval::top1(&preds, &labels)
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}
