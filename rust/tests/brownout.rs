//! Precision-brownout end-to-end: overload plus connection chaos against
//! a front door serving a full 8/4/2-bit rung ladder.
//!
//! The acceptance invariant from the brownout issue: under sustained
//! closed-loop overload (8 clients against 1 worker and a depth-1 queue,
//! through a timing-chaos proxy), the run must complete with **zero
//! failed and zero dropped requests** — every request is either answered
//! (possibly at a degraded rung) or shed with a clean 429 after the
//! ladder is exhausted — and the degraded rungs must actually have
//! served traffic (`precision_served{4|2} > 0`, corroborated client-side
//! by the `bits` response field).

use std::sync::Arc;
use std::time::{Duration, Instant};

use pdq::coordinator::brownout::BrownoutState;
use pdq::coordinator::{BrownoutConfig, Server, ServerConfig};
use pdq::engine::{Engine, FloatEngine, Int8Engine, VariantKey, VariantSpec};
use pdq::net::chaos::{ChaosConfig, ChaosListener};
use pdq::net::loadgen::{self, LoadMode, LoadgenConfig};
use pdq::net::wire::{Client, InferOutcome};
use pdq::net::{FrontDoor, FrontDoorConfig};
use pdq::nn::int8_exec::Int8Executor;
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{Graph, QuantMode};
use pdq::quant::Granularity;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::Pcg32;

const HW: usize = 6;
const CIN: usize = 2;

/// conv(2→3, 3x3) → relu → gap, input 6×6×2; weights seeded.
fn brownout_graph() -> Arc<Graph> {
    let mut rng = Pcg32::new(0xB10_0B17);
    let mut g = Graph::new(Shape::hwc(HW, HW, CIN));
    let x = g.input();
    let w: Vec<f32> = (0..3 * 9 * CIN).map(|_| rng.normal_ms(0.0, 0.4)).collect();
    let c = g.conv(
        x,
        Tensor::from_vec(Shape::ohwi(3, 3, 3, CIN), w),
        vec![0.02, -0.03, 0.05],
        ConvGeom::same(3, 1),
    );
    let r = g.relu(c);
    let p = g.global_avg_pool(r);
    g.mark_output(p);
    Arc::new(g)
}

/// fp32 + the full int8 rung ladder (8, 4, 2 bits) for model `"t"`.
fn ladder_variants() -> Vec<(VariantKey, Arc<dyn Engine>)> {
    let graph = brownout_graph();
    let mut rng = Pcg32::new(0xB10_CA11);
    let calib: Vec<Tensor<f32>> = (0..8)
        .map(|_| {
            let d: Vec<f32> = (0..HW * HW * CIN).map(|_| rng.uniform()).collect();
            Tensor::from_vec(Shape::hwc(HW, HW, CIN), d)
        })
        .collect();
    let mode = QuantMode::Probabilistic;
    let gran = Granularity::PerTensor;
    let mut ex = QuantExecutor::new(
        Arc::clone(&graph),
        QuantSettings { mode, granularity: gran, ..Default::default() },
    );
    ex.calibrate(&calib);
    let base = Int8Executor::lower(&ex, gran).expect("lowering");
    let spec = |bits| VariantSpec::Int8 { mode, weight_gran: gran, bits };
    let mut variants: Vec<(VariantKey, Arc<dyn Engine>)> =
        vec![(VariantKey::new("t", VariantSpec::Fp32), Arc::new(FloatEngine::new(graph)))];
    for bits in [8u32, 4, 2] {
        let rung = base.rung(bits).expect("rung derivation");
        variants.push((
            VariantKey::new("t", spec(bits)),
            Arc::new(Int8Engine::new(Arc::new(rung))),
        ));
    }
    variants
}

/// Overload (8 closed-loop clients vs 1 worker, depth-1 queue) through a
/// timing-only chaos proxy: zero failed, zero dropped, degraded rungs
/// actually served, clean drain with no leaked permits.
#[test]
fn overload_with_chaos_degrades_instead_of_failing() {
    let server = Arc::new(Server::start(
        ladder_variants(),
        ServerConfig {
            workers_per_variant: 1,
            max_queue_depth: 1,
            // Dwell of an hour: escalation stays instant (dwell only gates
            // de-escalation), so once overload bites, the state is pinned
            // for the whole test — no timing-dependent flapping.
            brownout: Some(BrownoutConfig {
                min_dwell: Duration::from_secs(3600),
                ..Default::default()
            }),
            ..Default::default()
        },
    ));
    let fd = FrontDoor::start(Arc::clone(&server), FrontDoorConfig::default()).unwrap();
    let proxy = ChaosListener::start(
        "127.0.0.1:0",
        &fd.local_addr().to_string(),
        ChaosConfig {
            seed: 0xB10_0003,
            max_chunk: 7,
            would_block_every: 5,
            latency: Duration::from_micros(150),
            latency_every: 6,
            disconnect_every: 0, // timing faults only: failures would be ours
            ..ChaosConfig::default()
        },
    )
    .unwrap();

    let report = loadgen::run(&LoadgenConfig {
        target: proxy.local_addr().to_string(),
        mode: LoadMode::Closed,
        concurrency: 8, // 8× the single worker: sustained overload
        duration: Duration::from_secs(2),
        variants: vec!["t|int8-ours-t".into()],
        seed: 0xB10_10AD,
        ..LoadgenConfig::default()
    })
    .unwrap();

    assert!(report.total.ok > 0, "overload must not stop all traffic: {:?}", report.total);
    assert_eq!(
        report.total.failed, 0,
        "brownout must degrade, never fail, before ladder exhaustion: {:?}",
        report.total
    );
    assert_eq!(report.total.dropped, 0, "no transport-level losses: {:?}", report.total);

    // The ladder actually degraded: server-side counters and the
    // client-visible `bits` response field agree that 4- or 2-bit rungs
    // served real traffic.
    let m = server.metrics();
    let degraded_served = m.precision_served(4) + m.precision_served(2);
    assert!(
        degraded_served > 0,
        "8 clients vs 1 worker must push the controller past Normal \
         (served: 8→{} 4→{} 2→{})",
        m.precision_served(8),
        m.precision_served(4),
        m.precision_served(2)
    );
    let client_degraded: u64 = report
        .total
        .served_bits
        .iter()
        .filter(|(bits, _)| **bits == 4 || **bits == 2)
        .map(|(_, n)| **n)
        .sum();
    assert!(
        client_degraded > 0,
        "degraded responses must carry their bits on the wire: {:?}",
        report.total.served_bits
    );

    // Forced Degrade2: the very next request must be served at exactly
    // 2 bits and say so in the response preamble.
    server.brownout().expect("brownout enabled").force_state(BrownoutState::Degrade2, Instant::now());
    let mut rng = Pcg32::new(0xB10_0D1E);
    let d: Vec<f32> = (0..HW * HW * CIN).map(|_| rng.uniform()).collect();
    let img = Tensor::from_vec(Shape::hwc(HW, HW, CIN), d);
    let key = VariantKey::parse_wire("t|int8-ours-t").unwrap();
    let mut direct = Client::new(&fd.local_addr().to_string());
    match direct.post_infer(&key, 424_242, &img).unwrap() {
        InferOutcome::Ok(resp) => {
            assert_eq!(resp.id, 424_242);
            assert_eq!(resp.bits, 2, "Degrade2 must serve the 2-bit rung");
        }
        InferOutcome::Rejected { retry_after_ms } => {
            panic!("unloaded post-run request was shed (retry hint {retry_after_ms} ms)")
        }
        InferOutcome::Failed { status, error } => {
            panic!("unloaded post-run request failed: http {status}: {error}")
        }
    }
    drop(direct);

    proxy.shutdown();
    let metrics = fd.shutdown();
    for (key, depth) in server.admission_depths() {
        assert_eq!(depth, 0, "leaked admission permit on {}", key.wire());
    }
    assert_eq!(metrics.malformed(), 0, "chaos mangles timing, never bytes");
}
