//! The online-adaptation loop end to end (`pdq::adapt`): under a
//! mid-stream corruption shift the drift score crosses the threshold, a
//! shadow recalibration fires exactly once per cooldown window, the grid
//! swap is atomic (in-flight sessions finish on the old grids; responses
//! are bit-exact within an epoch), post-swap accuracy on the shifted
//! stream strictly improves over the frozen baseline, and with adaptation
//! off the hot path is bit-identical to the plain engine path.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use pdq::adapt::{
    AdaptConfig, AdaptManager, DriftConfig, ObserverConfig, PolicyConfig, RecalBackend,
    RecalPolicy,
};
use pdq::coordinator::calibrate::demo_model;
use pdq::coordinator::{Server, ServerConfig};
use pdq::data::shapes::{self, Split};
use pdq::engine::{
    calibration_images, Engine, FloatEngine, Int8Engine, SessionPool, VariantKey, VariantSpec,
    CALIB_SIZE,
};
use pdq::models::Model;
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{Int8Executor, QuantMode};
use pdq::quant::Granularity;
use pdq::tensor::Tensor;

/// A strong, deterministic §5.2-style shift: compress the image into a
/// bright band, far outside the calibration distribution.
fn shift_image(img: &Tensor<f32>) -> Tensor<f32> {
    let mut out = img.clone();
    for v in out.data_mut() {
        *v = (0.25 * *v + 0.70).clamp(0.0, 1.0);
    }
    out
}

/// Calibrated int8-static program + engine for the demo model.
fn int8_static(model: &Model, calib: &[Tensor<f32>]) -> (Arc<Int8Executor>, Arc<dyn Engine>) {
    let settings = QuantSettings {
        mode: QuantMode::Static,
        granularity: Granularity::PerTensor,
        ..Default::default()
    };
    let mut qex = QuantExecutor::new(Arc::clone(&model.graph), settings);
    qex.calibrate(calib);
    let ex = Arc::new(Int8Executor::lower(&qex, Granularity::PerTensor).expect("lowering"));
    let engine: Arc<dyn Engine> = Arc::new(Int8Engine::new(Arc::clone(&ex)));
    (ex, engine)
}

fn int8_static_key(model: &str) -> VariantKey {
    VariantKey::new(
        model,
        VariantSpec::Int8 { mode: QuantMode::Static, weight_gran: Granularity::PerTensor, bits: 8 },
    )
}

/// Σ relative error of the first output vs the fp32 reference, over a set.
fn total_rel_err(engine: &dyn Engine, fp32: &[Vec<f32>], images: &[Tensor<f32>]) -> f64 {
    let mut session = engine.compile().expect("compiles");
    let mut total = 0.0f64;
    for (img, want) in images.iter().zip(fp32) {
        let got = session.run(img).expect("runs");
        let num: f64 = got[0]
            .data()
            .iter()
            .zip(want)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let den: f64 = want.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().max(1e-9);
        total += (num / den).sqrt();
    }
    total
}

#[test]
fn drift_rises_recal_fires_once_and_accuracy_improves() {
    let model = demo_model("m");
    let calib = calibration_images(model.task, CALIB_SIZE);
    let (ex, frozen) = int8_static(&model, &calib);
    let key = int8_static_key("m");

    let n = 32usize;
    let clean: Vec<Tensor<f32>> = shapes::dataset(model.task, Split::Test, n)
        .iter()
        .map(|s| s.image_f32())
        .collect();
    let shifted: Vec<Tensor<f32>> = clean.iter().map(shift_image).collect();

    let cfg = AdaptConfig {
        observer: ObserverConfig {
            sample_every: 1,
            tap_gamma: 2,
            window_cap: n as u64,
            ..Default::default()
        },
        drift: DriftConfig { threshold: 0.5, min_requests: 8, ..Default::default() },
        policy: PolicyConfig {
            policy: RecalPolicy::DriftTriggered,
            // One cooldown window spans the whole test: sustained drift
            // must produce exactly one recalibration.
            cooldown: Duration::from_secs(3600),
        },
        ..Default::default()
    };
    let mut manager = AdaptManager::new(cfg);
    let cell = manager
        .register(
            key.clone(),
            Arc::clone(&frozen),
            RecalBackend::Int8Refold(Mutex::new(Arc::clone(&ex))),
            &clean,
        )
        .expect("register");
    let pool = SessionPool::over(Arc::clone(&cell));

    // --- clean phase: no drift, no recalibration ---------------------------
    for img in &clean {
        pool.acquire().unwrap().run(img).unwrap();
    }
    let probe = manager.probe();
    assert!(
        probe[0].1.aggregate < 0.5,
        "clean traffic vs clean reference must stay calm, got {}",
        probe[0].1.aggregate
    );
    assert!(manager.tick().is_empty(), "no recalibration on clean traffic");

    // --- the shift lands: drift crosses the threshold ----------------------
    for img in &shifted {
        pool.acquire().unwrap().run(img).unwrap();
    }
    let probe = manager.probe();
    assert!(
        probe[0].1.aggregate >= 0.5,
        "shifted traffic must cross the drift threshold, got {}",
        probe[0].1.aggregate
    );

    // --- exactly one recalibration per cooldown window ---------------------
    let outcomes = manager.tick();
    assert_eq!(outcomes.len(), 1, "the drifted variant fires");
    assert!(outcomes[0].fired, "{}", outcomes[0].detail);
    assert_eq!(outcomes[0].detail, "int8-refold");
    assert_eq!(outcomes[0].epoch, 1);
    // Sustained drift, repeated ticks: the cooldown holds it to one.
    for _ in 0..3 {
        for img in &shifted {
            pool.acquire().unwrap().run(img).unwrap();
        }
        assert!(manager.tick().is_empty(), "cooldown must suppress repeat fires");
    }
    let status = manager.status().remove(0);
    assert_eq!(status.recalibrations, 1);
    assert_eq!(status.epoch, 1);
    assert!(status.peak_drift >= 0.5);

    // --- post-swap accuracy strictly improves on the shifted stream --------
    let fp32_engine = FloatEngine::new(Arc::clone(&model.graph));
    let mut fp32 = fp32_engine.compile().unwrap();
    let reference: Vec<Vec<f32>> =
        shifted.iter().map(|img| fp32.run(img).unwrap()[0].data().to_vec()).collect();
    let adapted = cell.current().1;
    let err_frozen = total_rel_err(frozen.as_ref(), &reference, &shifted);
    let err_adapted = total_rel_err(adapted.as_ref(), &reference, &shifted);
    assert!(
        err_adapted < err_frozen,
        "refolded grids must beat the frozen calibration on shifted data: \
         adapted {err_adapted:.4} vs frozen {err_frozen:.4}"
    );
}

#[test]
fn epoch_swap_is_atomic_and_bit_exact_within_epoch() {
    let model = demo_model("m");
    let calib = calibration_images(model.task, CALIB_SIZE);
    let (ex, engine) = int8_static(&model, &calib);
    let key = int8_static_key("m");
    let cfg = AdaptConfig {
        observer: ObserverConfig { sample_every: 1, ..Default::default() },
        drift: DriftConfig { min_requests: 1, ..Default::default() },
        policy: PolicyConfig { policy: RecalPolicy::Manual, cooldown: Duration::ZERO },
        ..Default::default()
    };
    let mut manager = AdaptManager::new(cfg);
    let cell = manager
        .register(
            key,
            Arc::clone(&engine),
            RecalBackend::Int8Refold(Mutex::new(Arc::clone(&ex))),
            &calib,
        )
        .expect("register");
    let pool = SessionPool::over(Arc::clone(&cell));
    let img = shift_image(&calib[0]);

    // Epoch 0: repeated runs are bit-exact.
    let before_a = pool.acquire().unwrap().run(&img).unwrap()[0].data().to_vec();
    let before_b = pool.acquire().unwrap().run(&img).unwrap()[0].data().to_vec();
    assert_eq!(before_a, before_b, "bit-exact within epoch 0");

    // Feed shifted stats so a manual refold has a window to work from,
    // then hold an in-flight session across the swap.
    for _ in 0..8 {
        pool.acquire().unwrap().run(&img).unwrap();
    }
    let mut held = pool.acquire().unwrap();
    assert_eq!(held.epoch(), 0);
    let outcomes = manager.recalibrate_now(None);
    assert!(outcomes[0].fired, "{}", outcomes[0].detail);
    assert_eq!(outcomes[0].epoch, 1);

    // The held session still executes the OLD grids, bit-for-bit.
    let during = held.run(&img).unwrap()[0].data().to_vec();
    assert_eq!(during, before_a, "in-flight work finishes on the old epoch");
    drop(held);

    // New checkouts see the new grids: bit-exact within epoch 1, and the
    // grids actually moved (the shifted stats changed the frozen ranges).
    let s = pool.acquire().unwrap();
    assert_eq!(s.epoch(), 1);
    drop(s);
    let after_a = pool.acquire().unwrap().run(&img).unwrap()[0].data().to_vec();
    let after_b = pool.acquire().unwrap().run(&img).unwrap()[0].data().to_vec();
    assert_eq!(after_a, after_b, "bit-exact within epoch 1");
    assert_ne!(after_a, before_a, "the swap must change the served grids");
}

/// With adaptation off (`Server::start`), the serving hot path is
/// bit-identical to compiling and running the engine directly — no
/// observer, no sampling, no epoch machinery in the way.
#[test]
fn adapt_off_is_bit_identical_to_plain_engine() {
    let model = demo_model("m");
    let calib = calibration_images(model.task, CALIB_SIZE);
    let (_, engine) = int8_static(&model, &calib);
    let key = int8_static_key("m");
    let server = Server::start(
        vec![(key.clone(), Arc::clone(&engine))],
        ServerConfig::default(),
    );
    assert!(server.adapt().is_none(), "plain start has no adaptation");
    let mut direct = engine.compile().unwrap();
    let images: Vec<Tensor<f32>> = calib.iter().take(6).cloned().collect();
    for (i, img) in images.iter().enumerate() {
        let rx = server.submit(key.clone(), i as u64, img.clone()).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let served = resp.result.expect("serves");
        let want = direct.run(img).unwrap();
        assert_eq!(
            served[0].data(),
            want[0].data(),
            "request {i}: served output must be bit-identical to the plain engine"
        );
    }
    server.drain();
}

/// The serving integration: an adaptive coordinator behind the HTTP front
/// door — `/v1/drift` reports status, `POST /v1/recalibrate` fires the
/// int8 refold, and the Prometheus exposition carries the gauges.
#[test]
fn http_drift_and_recalibrate_endpoints() {
    use pdq::net::{wire, FrontDoor, FrontDoorConfig};
    use pdq::util::json::Json;

    let model = demo_model("m");
    let calib = calibration_images(model.task, CALIB_SIZE);
    let (ex, engine) = int8_static(&model, &calib);
    let key = int8_static_key("m");
    let cfg = AdaptConfig {
        observer: ObserverConfig { sample_every: 1, ..Default::default() },
        // Manual policy: the background worker observes but never fires on
        // its own, so the endpoint's effect is deterministic.
        policy: PolicyConfig { policy: RecalPolicy::Manual, cooldown: Duration::ZERO },
        poll_interval: Duration::from_millis(50),
        ..Default::default()
    };
    let mut manager = AdaptManager::new(cfg);
    let cell = manager
        .register(
            key.clone(),
            engine,
            RecalBackend::Int8Refold(Mutex::new(Arc::clone(&ex))),
            &calib,
        )
        .expect("register");
    let server = Arc::new(Server::start_adaptive(
        vec![(key.clone(), cell)],
        ServerConfig::default(),
        Arc::new(manager),
    ));
    let fd = FrontDoor::start(Arc::clone(&server), FrontDoorConfig::default()).unwrap();
    let addr = fd.local_addr().to_string();
    let mut client = wire::Client::new(&addr);

    // Baseline status.
    let resp = client.get("/v1/drift").unwrap();
    assert_eq!(resp.status, 200);
    let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let variants = j.get("variants").unwrap().as_arr().unwrap();
    assert_eq!(variants.len(), 1);
    assert_eq!(variants[0].get("variant").unwrap().as_str(), Some("m|int8-static-t"));
    assert_eq!(variants[0].get("epoch").unwrap().as_usize(), Some(0));
    assert_eq!(variants[0].get("backend").unwrap().as_str(), Some("int8-refold"));

    // Drive shifted traffic over the socket so a live window accumulates.
    let img = shift_image(&calib[0]);
    for i in 0..10u64 {
        match client.post_infer(&key, i, &img).unwrap() {
            wire::InferOutcome::Ok(_) => {}
            other => panic!(
                "infer must succeed, got {}",
                match other {
                    wire::InferOutcome::Rejected { .. } => "rejected",
                    wire::InferOutcome::Failed { .. } => "failed",
                    wire::InferOutcome::Ok(_) => unreachable!(),
                }
            ),
        }
    }

    // Manual recalibration through the endpoint.
    let resp = client
        .request("POST", "/v1/recalibrate?variant=m|int8-static-t", "", &[])
        .unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let outcomes = j.get("outcomes").unwrap().as_arr().unwrap();
    assert_eq!(outcomes.len(), 1);
    assert_eq!(outcomes[0].get("fired").unwrap().as_bool(), Some(true));
    assert_eq!(outcomes[0].get("epoch").unwrap().as_usize(), Some(1));

    // Status reflects the swap; Prometheus carries the gauges.
    let resp = client.get("/v1/drift").unwrap();
    let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let v = &j.get("variants").unwrap().as_arr().unwrap()[0];
    assert_eq!(v.get("epoch").unwrap().as_usize(), Some(1));
    assert_eq!(v.get("recalibrations").unwrap().as_usize(), Some(1));
    let prom = client.get("/metrics?format=prometheus").unwrap();
    let body = String::from_utf8_lossy(&prom.body).to_string();
    assert!(body.contains("pdq_drift_score{variant=\"m|int8-static-t\"}"), "{body}");
    assert!(body.contains("pdq_recalibrations_total{variant=\"m|int8-static-t\"} 1"), "{body}");
    assert!(body.contains("pdq_engine_epoch{variant=\"m|int8-static-t\"} 1"), "{body}");
    // Unknown filter is a 404; serving still works post-swap.
    let resp = client
        .request("POST", "/v1/recalibrate?variant=ghost|fp32", "", &[])
        .unwrap();
    assert_eq!(resp.status, 404);
    match client.post_infer(&key, 99, &img).unwrap() {
        wire::InferOutcome::Ok(r) => assert_eq!(r.id, 99),
        _ => panic!("post-swap inference must succeed"),
    }

    let metrics = fd.shutdown();
    assert!(metrics.responses() >= 11);
    // Per-variant breakdown followed the adaptive traffic too.
    assert!(metrics.variant_responses("m|int8-static-t") >= 11);
}
