//! SLO-autopilot end-to-end: the acceptance loop from the autopilot issue.
//!
//! 1. Under closed-loop overload with an oversized `--max-queue`, the
//!    autopilot must shrink the queue depth within its cooldown cadence,
//!    attribute every retune in the decision log with histogram evidence
//!    (a ledger snapshot alongside before/after knob values), commit
//!    `autopilot.retune:*` spans into the flight recorder, and deliver a
//!    better client-observed p99 than the same seeded workload served
//!    with the autopilot off.
//! 2. Sharing a server with the precision-brownout controller must not
//!    make brownout flap: the autopilot absorbs queue pressure by
//!    retuning knobs while brownout stays in `Normal`.
//! 3. Continuous 1-in-N profiling must be invisible in the arithmetic:
//!    sampled requests carry a trace id and kernel spans, non-sampled
//!    requests are bit-identical to an unprofiled server's responses.

use std::sync::Arc;
use std::time::Duration;

use pdq::coordinator::batcher::BatchPolicy;
use pdq::coordinator::{
    AutopilotConfig, BrownoutConfig, BrownoutState, Server, ServerConfig,
};
use pdq::engine::{
    Engine, EngineError, FloatEngine, Int8Engine, KernelTrace, RunTap, Session, VariantKey,
    VariantSpec,
};
use pdq::net::loadgen::{self, LoadMode, LoadgenConfig};
use pdq::net::wire::{self, TENSOR_CONTENT_TYPE};
use pdq::net::{FrontDoor, FrontDoorConfig};
use pdq::nn::int8_exec::Int8Executor;
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{Graph, QuantMode};
use pdq::quant::Granularity;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::json::Json;
use pdq::util::Pcg32;

const HW: usize = 4;
const CIN: usize = 2;

/// conv(2→3, 3x3) → relu → gap, input 4×4×2; weights seeded.
fn tiny_graph() -> Arc<Graph> {
    let mut rng = Pcg32::new(0xA070_0717);
    let mut g = Graph::new(Shape::hwc(HW, HW, CIN));
    let x = g.input();
    let w: Vec<f32> = (0..3 * 9 * CIN).map(|_| rng.normal_ms(0.0, 0.4)).collect();
    let c = g.conv(
        x,
        Tensor::from_vec(Shape::ohwi(3, 3, 3, CIN), w),
        vec![0.02, -0.03, 0.05],
        ConvGeom::same(3, 1),
    );
    let r = g.relu(c);
    let p = g.global_avg_pool(r);
    g.mark_output(p);
    Arc::new(g)
}

fn test_image(seed: u64) -> Tensor<f32> {
    let mut rng = Pcg32::new(seed);
    let d: Vec<f32> = (0..HW * HW * CIN).map(|_| rng.uniform()).collect();
    Tensor::from_vec(Shape::hwc(HW, HW, CIN), d)
}

// ---- a deliberately slow fp32 engine ----
//
// The tiny graph executes in microseconds — far too fast for queueing to
// dominate. `SlowEngine` wraps the float engine and sleeps a fixed 2 ms
// per run, so 8 closed-loop clients against 1 worker build a real queue
// and the SLO ledger's dominant stage is unambiguously `queue`.

struct SlowEngine {
    inner: FloatEngine,
    delay: Duration,
}

struct SlowSession {
    inner: Box<dyn Session>,
    delay: Duration,
}

impl Engine for SlowEngine {
    fn spec(&self) -> VariantSpec {
        self.inner.spec()
    }
    fn input_shape(&self) -> &Shape {
        self.inner.input_shape()
    }
    fn compile(&self) -> Result<Box<dyn Session>, EngineError> {
        Ok(Box::new(SlowSession { inner: self.inner.compile()?, delay: self.delay }))
    }
}

impl Session for SlowSession {
    fn run(&mut self, input: &Tensor<f32>) -> Result<Vec<Tensor<f32>>, EngineError> {
        std::thread::sleep(self.delay);
        self.inner.run(input)
    }
    fn run_tapped(
        &mut self,
        input: &Tensor<f32>,
        tap: &mut RunTap,
    ) -> Result<Vec<Tensor<f32>>, EngineError> {
        std::thread::sleep(self.delay);
        self.inner.run_tapped(input, tap)
    }
    fn run_traced(
        &mut self,
        input: &Tensor<f32>,
        ktrace: &mut KernelTrace,
    ) -> Result<Vec<Tensor<f32>>, EngineError> {
        std::thread::sleep(self.delay);
        self.inner.run_traced(input, ktrace)
    }
    fn input_shape(&self) -> &Shape {
        self.inner.input_shape()
    }
}

fn slow_variants(delay: Duration) -> Vec<(VariantKey, Arc<dyn Engine>)> {
    vec![(
        VariantKey::new("t", VariantSpec::Fp32),
        Arc::new(SlowEngine { inner: FloatEngine::new(tiny_graph()), delay }),
    )]
}

/// 4 ms budget, aggressive cadence so the loop converges inside a short
/// test: dwell 1 tick, 50 ms cooldown, 15 ms tick, max step (50%).
fn test_autopilot() -> AutopilotConfig {
    AutopilotConfig::parse("depth=2..64,step=0.5,exit=0.5,dwell=1,cooldown_ms=50,tick_ms=15", 4_000)
        .expect("valid autopilot spec")
}

struct RunOutcome {
    measured_p99_us: f64,
    final_depth: usize,
}

/// Serve the seeded overload workload (8 closed-loop clients vs 1 worker
/// behind an oversized depth-64 queue) and measure steady-state p99 in a
/// second phase so convergence transients don't pollute the comparison.
fn overload_run(autopilot: bool) -> RunOutcome {
    let server = Arc::new(Server::start(
        slow_variants(Duration::from_millis(2)),
        ServerConfig {
            workers_per_variant: 1,
            max_queue_depth: 64, // oversized: 8× the client count
            policy: BatchPolicy { max_batch: 1, deadline: Duration::from_micros(100) },
            autopilot: autopilot.then(test_autopilot),
            ..Default::default()
        },
    ));
    let fd = FrontDoor::start(Arc::clone(&server), FrontDoorConfig::default()).unwrap();
    let addr = fd.local_addr().to_string();

    // Phase A: converge. The same seed on both sides of the comparison.
    let converge = loadgen::run(&LoadgenConfig {
        target: addr.clone(),
        mode: LoadMode::Closed,
        concurrency: 8,
        duration: Duration::from_secs(2),
        variants: vec!["t|fp32".into()],
        seed: 0xA070_0001,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert!(converge.total.ok > 0, "converge phase served nothing: {:?}", converge.total);
    assert_eq!(converge.total.failed, 0, "converge failures: {:?}", converge.total);
    assert_eq!(converge.total.dropped, 0, "converge drops: {:?}", converge.total);

    // Phase B: measure steady state under a fresh seed (same on both
    // sides), after the autopilot — when enabled — has had 2 s and ~25
    // cooldown windows to act.
    let measure = loadgen::run(&LoadgenConfig {
        target: addr.clone(),
        mode: LoadMode::Closed,
        concurrency: 8,
        duration: Duration::from_secs(2),
        variants: vec!["t|fp32".into()],
        seed: 0xA070_0002,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert!(measure.total.ok > 0, "measure phase served nothing: {:?}", measure.total);
    assert_eq!(measure.total.failed, 0, "measure failures: {:?}", measure.total);
    assert_eq!(measure.total.dropped, 0, "measure drops: {:?}", measure.total);

    let final_depth = server.max_queue_depth();

    if autopilot {
        // The controller acted, repeatedly, and always on the queue knob:
        // this workload is queue-dominated by construction.
        let ctl = Arc::clone(server.autopilot().expect("autopilot enabled"));
        assert!(ctl.actions() >= 3, "expected ≥3 retunes, got {}", ctl.actions());
        let decisions = ctl.decisions_json();
        assert!(!decisions.is_empty(), "retunes must leave decision evidence");
        for d in &decisions {
            assert_eq!(
                d.get("knob").and_then(|k| k.as_str()),
                Some("max_queue_depth"),
                "queue-dominated overload must retune depth, got {d:?}"
            );
            let from = d.get("from").and_then(|v| v.as_f64()).unwrap();
            let to = d.get("to").and_then(|v| v.as_f64()).unwrap();
            assert!(to < from, "overload retunes must shrink: {from} -> {to}");
            assert!(
                d.get("ledger").is_some(),
                "every retune carries its histogram evidence: {d:?}"
            );
        }

        // The same evidence is visible to operators over HTTP …
        let mut client = wire::Client::new(&addr);
        let r = client.get("/v1/slo").unwrap();
        assert_eq!(r.status, 200);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        let ap = j.get("autopilot").expect("autopilot block in /v1/slo");
        assert_eq!(ap.get("enabled").unwrap().as_bool(), Some(true));
        assert!(
            !ap.get("decisions").unwrap().as_arr().unwrap().is_empty(),
            "/v1/slo must expose the decision ring"
        );
        drop(client);

        // … and as committed spans in the flight recorder.
        let retune_traces = fd
            .recorder()
            .snapshot()
            .iter()
            .filter(|t| t.variant.starts_with("autopilot.retune:"))
            .count();
        assert!(retune_traces > 0, "retunes must commit flight-recorder spans");
    } else {
        assert_eq!(final_depth, 64, "without the autopilot the knob must not move");
    }

    fd.shutdown();
    for (key, depth) in server.admission_depths() {
        assert_eq!(depth, 0, "leaked admission permit on {}", key.wire());
    }
    RunOutcome { measured_p99_us: measure.total.p99_us, final_depth }
}

/// Overload + oversized `--max-queue`: the autopilot shrinks the depth,
/// leaves attributed evidence everywhere it should, and the steady-state
/// client p99 beats the autopilot-off baseline on the same seeds.
#[test]
fn autopilot_shrinks_oversized_depth_and_improves_p99() {
    let with = overload_run(true);
    let without = overload_run(false);

    assert!(
        with.final_depth <= 8,
        "depth must converge well below the oversized 64 (got {})",
        with.final_depth
    );
    assert!(
        with.measured_p99_us < 0.9 * without.measured_p99_us,
        "autopilot must improve steady-state p99: {:.0} us (on) vs {:.0} us (off)",
        with.measured_p99_us,
        without.measured_p99_us
    );
}

/// Brownout and autopilot on the same server: the autopilot retunes
/// knobs for its tight 4 ms budget while brownout — whose own SLO is a
/// lenient 500 ms — never leaves `Normal`. No cross-controller flapping.
#[test]
fn autopilot_and_brownout_do_not_flap_each_other() {
    let server = Arc::new(Server::start(
        slow_variants(Duration::from_millis(2)),
        ServerConfig {
            workers_per_variant: 1,
            max_queue_depth: 64,
            policy: BatchPolicy { max_batch: 1, deadline: Duration::from_micros(100) },
            brownout: Some(BrownoutConfig { slo_p99_us: 500_000.0, ..Default::default() }),
            autopilot: Some(test_autopilot()),
            ..Default::default()
        },
    ));
    let fd = FrontDoor::start(Arc::clone(&server), FrontDoorConfig::default()).unwrap();

    let report = loadgen::run(&LoadgenConfig {
        target: fd.local_addr().to_string(),
        mode: LoadMode::Closed,
        concurrency: 8,
        duration: Duration::from_millis(1500),
        variants: vec!["t|fp32".into()],
        seed: 0xA070_0003,
        ..LoadgenConfig::default()
    })
    .unwrap();
    assert!(report.total.ok > 0);
    assert_eq!(report.total.failed, 0, "failures under overload: {:?}", report.total);

    let ctl = server.autopilot().expect("autopilot enabled");
    assert!(ctl.actions() >= 1, "the 4 ms budget must trigger retunes");
    assert_eq!(
        server.brownout().expect("brownout enabled").state(),
        BrownoutState::Normal,
        "brownout's 500 ms SLO is never threatened; the autopilot must not flap it"
    );
    fd.shutdown();
}

/// Continuous profiling, 1-in-3 deterministic sampling: sampled requests
/// carry a trace id on the wire and kernel spans in the recorder; every
/// response's tensors are bit-identical to an unprofiled server's.
#[test]
fn continuous_profiling_sampling_is_bit_identical() {
    fn int8_variant() -> Vec<(VariantKey, Arc<dyn Engine>)> {
        let graph = tiny_graph();
        let mut rng = Pcg32::new(0xA070_CA11);
        let calib: Vec<Tensor<f32>> = (0..8)
            .map(|_| {
                let d: Vec<f32> = (0..HW * HW * CIN).map(|_| rng.uniform()).collect();
                Tensor::from_vec(Shape::hwc(HW, HW, CIN), d)
            })
            .collect();
        let mode = QuantMode::Probabilistic;
        let gran = Granularity::PerTensor;
        let mut ex = QuantExecutor::new(
            Arc::clone(&graph),
            QuantSettings { mode, granularity: gran, ..Default::default() },
        );
        ex.calibrate(&calib);
        let base = Int8Executor::lower(&ex, gran).expect("lowering");
        let rung = base.rung(8).expect("8-bit rung");
        vec![(
            VariantKey::new("t", VariantSpec::Int8 { mode, weight_gran: gran, bits: 8 }),
            Arc::new(Int8Engine::new(Arc::new(rung))),
        )]
    }

    let serve = |profile_every: usize| {
        let server = Arc::new(Server::start(int8_variant(), ServerConfig::default()));
        FrontDoor::start(
            server,
            FrontDoorConfig { profile_every, profile_seed: 0, ..FrontDoorConfig::default() },
        )
        .unwrap()
    };
    let fd_plain = serve(0);
    let fd_sampled = serve(3);

    let key = VariantKey::parse_wire("t|int8-ours-t").unwrap();
    let img = test_image(0xA070_0D1E);
    let run_all = |fd: &FrontDoor| -> Vec<(Option<String>, Vec<u32>)> {
        let mut client = wire::Client::new(&fd.local_addr().to_string());
        (0..9u64)
            .map(|id| {
                let body = wire::encode_infer_request(&key, id, &img);
                let parts =
                    client.request("POST", "/v1/infer", TENSOR_CONTENT_TYPE, &body).unwrap();
                assert_eq!(parts.status, 200, "infer {id} failed");
                let resp = wire::decode_infer_response(&parts.body).unwrap();
                assert_eq!(resp.id, id);
                let bits: Vec<u32> =
                    resp.outputs.iter().flat_map(|t| t.data().iter().map(|v| v.to_bits())).collect();
                (parts.header("x-pdq-trace").map(str::to_string), bits)
            })
            .collect()
    };

    let plain = run_all(&fd_plain);
    let sampled = run_all(&fd_sampled);

    for (i, ((h_plain, bits_plain), (h_sampled, bits_sampled))) in
        plain.iter().zip(sampled.iter()).enumerate()
    {
        assert!(h_plain.is_none(), "unprofiled server leaked a trace id on request {i}");
        assert_eq!(
            h_sampled.is_some(),
            i % 3 == 0,
            "1-in-3 seed-0 sampling must tag exactly requests 0,3,6 (request {i})"
        );
        assert_eq!(
            bits_plain, bits_sampled,
            "sampling must never perturb the arithmetic (request {i})"
        );
    }
    // All nine responses on each server are the same input, so their
    // outputs must be identical bit patterns — sampled or not.
    for (i, (_, bits)) in sampled.iter().enumerate() {
        assert_eq!(*bits, sampled[0].1, "request {i} diverged from request 0");
    }

    let (recent_plain, _) = fd_plain.recorder().counts();
    let (recent_sampled, _) = fd_sampled.recorder().counts();
    assert_eq!(recent_plain, 0, "profile_every=0 must record nothing");
    assert_eq!(recent_sampled, 3, "1-in-3 over 9 requests records exactly 3");
    let with_kernels = fd_sampled
        .recorder()
        .snapshot()
        .iter()
        .filter(|t| !t.kernel.is_empty())
        .count();
    assert_eq!(with_kernels, 3, "sampled int8 requests must carry kernel spans");

    fd_plain.shutdown();
    fd_sampled.shutdown();
}
