//! Chaos serving integration: the full front door driven through
//! fault-injected connections.
//!
//! The invariant under test: [`pdq::net::chaos`] mangles *timing and
//! connection lifetime*, never bytes — so whatever it does, the server
//! must never mis-parse a request (`metrics.malformed() == 0`), never
//! leak an admission permit (all depths 0 after drain), and always drain
//! cleanly. A timing-only chaos run (short reads, `WouldBlock` ticks,
//! latency) must additionally complete with **zero failed requests**;
//! a disconnect-storm run may fail individual requests but must leave
//! the server healthy.
//!
//! Plus the protocol-gap acceptance test: a chunked-encoded `/v1/infer`
//! request must round-trip bit-identically to its Content-Length twin.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use pdq::coordinator::server::Server;
use pdq::coordinator::ServerConfig;
use pdq::engine::{FloatEngine, VariantKey, VariantSpec};
use pdq::net::chaos::{ChaosConfig, ChaosListener};
use pdq::net::http::read_response;
use pdq::net::loadgen::{self, LoadMode, LoadgenConfig};
use pdq::net::wire;
use pdq::net::{FrontDoor, FrontDoorConfig};
use pdq::nn::Graph;
use pdq::tensor::{Shape, Tensor};

fn tiny_server() -> Arc<Server> {
    let mut g = Graph::new(Shape::hwc(2, 2, 1));
    let x = g.input();
    let r = g.relu(x);
    g.mark_output(r);
    let key = VariantKey::new("m", VariantSpec::Fp32);
    Arc::new(Server::start(
        vec![(key, Arc::new(FloatEngine::new(Arc::new(g))))],
        ServerConfig::default(),
    ))
}

fn start_stack() -> (Arc<Server>, FrontDoor) {
    let server = tiny_server();
    let fd = FrontDoor::start(Arc::clone(&server), FrontDoorConfig::default()).unwrap();
    (server, fd)
}

/// Timing-only chaos (no disconnects): a closed-loop load run through the
/// proxy must complete with zero failures, zero mis-parses, zero leaked
/// permits, and a clean drain.
#[test]
fn loadgen_survives_timing_chaos_with_zero_failures() {
    let (server, fd) = start_stack();
    let proxy = ChaosListener::start(
        "127.0.0.1:0",
        &fd.local_addr().to_string(),
        ChaosConfig {
            seed: 0xC4A0_0001,
            max_chunk: 5,
            would_block_every: 3,
            latency: Duration::from_micros(200),
            latency_every: 7,
            disconnect_every: 0, // timing faults only
            ..ChaosConfig::default()
        },
    )
    .unwrap();

    let report = loadgen::run(&LoadgenConfig {
        target: proxy.local_addr().to_string(),
        mode: LoadMode::Closed,
        concurrency: 3,
        duration: Duration::from_secs(2),
        ..LoadgenConfig::default()
    })
    .unwrap();

    assert!(report.total.ok > 0, "chaos must not stop all traffic");
    assert_eq!(
        report.total.failed, 0,
        "timing-only chaos must never fail a request: {:?}",
        report.total
    );
    assert!(proxy.connections() > 0, "traffic must actually flow through the proxy");
    proxy.shutdown();

    // Depth check only after the drain: shutdown() joins the connection
    // pool, so no handler can still be holding a permit.
    let metrics = fd.shutdown();
    for (key, depth) in server.admission_depths() {
        assert_eq!(depth, 0, "leaked admission permit on {}", key.wire());
    }
    assert_eq!(metrics.malformed(), 0, "chaos mangles timing, never bytes — no parse errors");
}

/// Disconnect storm: individual requests may fail, but the server must
/// stay healthy, never mis-parse, and never leak a permit.
#[test]
fn disconnect_storm_leaves_server_healthy() {
    let (server, fd) = start_stack();
    let proxy = ChaosListener::start(
        "127.0.0.1:0",
        &fd.local_addr().to_string(),
        ChaosConfig {
            seed: 0xC4A0_0002,
            max_chunk: 4,
            would_block_every: 4,
            disconnect_every: 2, // every other connection gets a kill budget
            ..ChaosConfig::default()
        },
    )
    .unwrap();

    let key = VariantKey::new("m", VariantSpec::Fp32);
    let img = Tensor::from_vec(Shape::hwc(2, 2, 1), vec![1.0, -2.0, 3.0, -4.0]);
    let mut ok = 0u32;
    for i in 0..24u64 {
        // Fresh client per iteration: maximizes the number of chaos
        // connections (each draws its own disconnect budget).
        let mut client = wire::Client::new(&proxy.local_addr().to_string());
        if let Ok(wire::InferOutcome::Ok(resp)) = client.post_infer(&key, i, &img) {
            assert_eq!(resp.id, i, "response crossed requests");
            assert_eq!(resp.outputs[0].data(), &[1.0, 0.0, 3.0, 0.0], "payload corrupted");
            ok += 1;
        }
    }
    proxy.shutdown();
    assert!(ok > 0, "some requests must survive the storm");

    // Direct (unproxied) traffic still works: the storm hurt only its own
    // connections.
    let mut direct = wire::Client::new(&fd.local_addr().to_string());
    assert_eq!(direct.get("/healthz").unwrap().status, 200);
    drop(direct);

    // Depth check only after the drain (a handler mid-request when its
    // client vanished may legitimately hold its permit a moment longer).
    let metrics = fd.shutdown();
    for (key, depth) in server.admission_depths() {
        assert_eq!(depth, 0, "disconnects leaked an admission permit on {}", key.wire());
    }
    assert_eq!(metrics.malformed(), 0, "disconnects must never look like malformed input");
}

/// One raw HTTP exchange; returns the decoded infer response.
fn raw_infer(addr: &str, head: &str, body: &[u8]) -> wire::InferResponseWire {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(head.as_bytes()).unwrap();
    s.write_all(body).unwrap();
    s.flush().unwrap();
    let parts = read_response(&mut s, 64 * 1024 * 1024).unwrap();
    assert_eq!(parts.status, 200, "infer must succeed: {:?}", String::from_utf8_lossy(&parts.body));
    wire::decode_infer_response(&parts.body).unwrap()
}

/// The ISSUE acceptance test: a chunked-encoded `/v1/infer` request must
/// produce a bit-identical inference result to its Content-Length twin.
#[test]
fn chunked_infer_matches_content_length_twin() {
    let (_server, fd) = start_stack();
    let addr = fd.local_addr().to_string();
    let key = VariantKey::new("m", VariantSpec::Fp32);
    let img = Tensor::from_vec(
        Shape::hwc(2, 2, 1),
        vec![0.1, -1.0 / 3.0, f32::MIN_POSITIVE, 1e30],
    );
    let body = wire::encode_infer_request(&key, 7, &img);

    let cl_head = format!(
        "POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        wire::TENSOR_CONTENT_TYPE,
        body.len()
    );
    let a = raw_infer(&addr, &cl_head, &body);

    // The same body, chunk-framed in small pieces with an extension and a
    // trailer — everything a real chunked encoder is allowed to emit.
    let mut chunked = Vec::new();
    for piece in body.chunks(5) {
        chunked.extend_from_slice(format!("{:x};why=not\r\n", piece.len()).as_bytes());
        chunked.extend_from_slice(piece);
        chunked.extend_from_slice(b"\r\n");
    }
    chunked.extend_from_slice(b"0\r\nX-Trailer: ignored\r\n\r\n");
    let te_head = format!(
        "POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        wire::TENSOR_CONTENT_TYPE
    );
    let b = raw_infer(&addr, &te_head, &chunked);

    assert_eq!(a.id, 7);
    assert_eq!(b.id, 7);
    assert_eq!(a.outputs.len(), b.outputs.len());
    for (ta, tb) in a.outputs.iter().zip(&b.outputs) {
        assert_eq!(ta.shape().dims(), tb.shape().dims());
        let bits_a: Vec<u32> = ta.data().iter().map(|x| x.to_bits()).collect();
        let bits_b: Vec<u32> = tb.data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits_a, bits_b, "chunked and content-length twins must match bit for bit");
    }
    fd.shutdown();
}
