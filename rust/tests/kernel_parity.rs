//! Kernel-parity property tests: the im2col/GEMM fast path and the arena
//! executors must be numerically faithful to the seed's naive loops
//! (`pdq::nn::ops::{conv2d, dwconv2d, linear}` — f64 accumulation), across
//! randomized shapes, stride ∈ {1, 2}, pad ∈ {0, same}, and γ ∈ {1, 2, 4}.

use std::sync::Arc;

use pdq::estimator::conv as conv_est;
use pdq::estimator::EstimatorScratch;
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{float_exec, memory, ops, Graph, QuantMode};
use pdq::quant::Granularity;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::check::{gen, Checker};
use pdq::util::Pcg32;

fn rand_tensor(rng: &mut Pcg32, shape: Shape) -> Tensor<f32> {
    let n = shape.numel();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_ms(0.1, 0.7)).collect())
}

/// |a - b| within 1e-5 absolute + 1e-5 relative to the tensor's magnitude.
fn assert_close(got: &[f32], want: &[f32], what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    let scale = want.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let tol = 1e-5 + 1e-5 * scale;
    for (i, (&a, &b)) in got.iter().zip(want.iter()).enumerate() {
        if (a - b).abs() > tol {
            return Err(format!("{what}[{i}]: {a} vs {b} (tol {tol})"));
        }
    }
    Ok(())
}

#[test]
fn conv_im2col_matches_naive_randomized() {
    Checker::new(0xF00D, 60).check("conv2d_into == conv2d", |rng| {
        let (h, w, cin, cout, k) = gen::conv_spec(rng);
        let stride = *rng.choice(&[1usize, 2]);
        let pad = *rng.choice(&[0usize, k / 2]);
        let geom = ConvGeom::new(k, k, stride, pad);
        let x = rand_tensor(rng, Shape::hwc(h, w, cin));
        let wt = rand_tensor(rng, Shape::ohwi(cout, k, k, cin));
        let bias: Vec<f32> = (0..cout).map(|_| rng.normal_ms(0.0, 0.3)).collect();
        let want = ops::conv2d(&x, &wt, &bias, &geom);
        let mut cols = Vec::new();
        let mut out = vec![0.0f32; want.numel()];
        ops::conv2d_into(&x, &wt, &bias, &geom, &mut cols, &mut out, |v, _| v);
        assert_close(&out, want.data(), &format!("conv h{h} w{w} cin{cin} cout{cout} k{k} s{stride} p{pad}"))
    });
}

#[test]
fn dwconv_matches_naive_randomized() {
    Checker::new(0xF00E, 60).check("dwconv2d_into == dwconv2d", |rng| {
        let (h, w, c, _, k) = gen::conv_spec(rng);
        let stride = *rng.choice(&[1usize, 2]);
        let pad = *rng.choice(&[0usize, k / 2]);
        let geom = ConvGeom::new(k, k, stride, pad);
        let x = rand_tensor(rng, Shape::hwc(h, w, c));
        let wt = rand_tensor(rng, Shape::new(&[c, k, k]));
        let bias: Vec<f32> = (0..c).map(|_| rng.normal_ms(0.0, 0.3)).collect();
        let want = ops::dwconv2d(&x, &wt, &bias, &geom);
        let mut scratch = Vec::new();
        let mut out = vec![0.0f32; want.numel()];
        ops::dwconv2d_into(&x, &wt, &bias, &geom, &mut scratch, &mut out, |v, _| v);
        assert_close(&out, want.data(), &format!("dwconv h{h} w{w} c{c} k{k} s{stride} p{pad}"))
    });
}

#[test]
fn linear_matches_naive_randomized() {
    Checker::new(0xF00F, 60).check("linear_into == linear", |rng| {
        let d = rng.int_range(1, 256) as usize;
        let hh = rng.int_range(1, 32) as usize;
        let wt = rand_tensor(rng, Shape::new(&[hh, d]));
        let x: Vec<f32> = (0..d).map(|_| rng.normal_ms(0.0, 1.0)).collect();
        let bias: Vec<f32> = (0..hh).map(|_| rng.normal_ms(0.0, 0.5)).collect();
        let want = ops::linear(&x, &wt, &bias);
        let mut out = vec![0.0f32; hh];
        ops::linear_into(&x, &wt, &bias, &mut out, |v, _| v);
        assert_close(&out, &want, &format!("linear h{hh} d{d}"))
    });
}

#[test]
fn estimator_scratch_matches_naive_across_gamma() {
    Checker::new(0xFA11, 40).check("integral scratch == naive", |rng| {
        let (h, w, cin, _cout, k) = gen::conv_spec(rng);
        let stride = *rng.choice(&[1usize, 2]);
        let geom = ConvGeom::same(k, stride);
        let gamma = *rng.choice(&[1usize, 2, 4]);
        let x = rand_tensor(rng, Shape::hwc(h, w, cin));
        let naive = conv_est::window_sums_naive(&x, &geom, gamma);
        let mut scratch = EstimatorScratch::default();
        conv_est::window_sums_integral_scratch(&x, &geom, gamma, &mut scratch);
        if naive.s1.len() != scratch.sums.s1.len() {
            return Err(format!("count {} vs {}", naive.s1.len(), scratch.sums.s1.len()));
        }
        for i in 0..naive.s1.len() {
            let (a, b) = (naive.s1[i], scratch.sums.s1[i]);
            if (a - b).abs() > 1e-6 * (1.0 + a.abs()) {
                return Err(format!("s1[{i}]: {a} vs {b}"));
            }
            let (a, b) = (naive.s2[i], scratch.sums.s2[i]);
            if (a - b).abs() > 1e-6 * (1.0 + a.abs()) {
                return Err(format!("s2[{i}]: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

fn residual_net(rng: &mut Pcg32) -> Arc<Graph> {
    let mut g = Graph::new(Shape::hwc(12, 12, 3));
    let x = g.input();
    let w1: Vec<f32> = (0..8 * 9 * 3).map(|_| rng.normal_ms(0.0, 0.25)).collect();
    let c1 = g.conv(
        x,
        Tensor::from_vec(Shape::ohwi(8, 3, 3, 3), w1),
        vec![0.05; 8],
        ConvGeom::same(3, 1),
    );
    let r1 = g.relu(c1);
    let wd: Vec<f32> = (0..8 * 9).map(|_| rng.normal_ms(0.1, 0.3)).collect();
    let d1 = g.dwconv(
        r1,
        Tensor::from_vec(Shape::new(&[8, 3, 3]), wd),
        vec![0.0; 8],
        ConvGeom::same(3, 1),
    );
    let a = g.add(d1, r1);
    let r2 = g.relu6(a);
    let p = g.global_avg_pool(r2);
    let wl: Vec<f32> = (0..5 * 8).map(|_| rng.normal_ms(0.0, 0.4)).collect();
    let l = g.linear(p, Tensor::from_vec(Shape::new(&[5, 8]), wl), vec![0.0; 5]);
    g.mark_output(l);
    Arc::new(g)
}

fn rand_image(rng: &mut Pcg32) -> Tensor<f32> {
    let data: Vec<f32> = (0..12 * 12 * 3).map(|_| rng.uniform()).collect();
    Tensor::from_vec(Shape::hwc(12, 12, 3), data)
}

#[test]
fn float_arena_matches_reference_engine() {
    let mut rng = Pcg32::new(0xABCD);
    let g = residual_net(&mut rng);
    let img = rand_image(&mut rng);
    let want = float_exec::run(&g, &img);
    let mut arena = memory::ExecArena::for_run(&g);
    let got = float_exec::run_with_arena(&g, &img, &mut arena);
    assert_eq!(got.len(), want.len());
    assert_close(got[0].data(), want[0].data(), "float arena").unwrap();
}

#[test]
fn quant_run_trace_identical_across_consecutive_calls() {
    // No stale-buffer bleed: two consecutive arena-based run_trace calls
    // (and runs through a reused worker arena) must be bit-identical.
    let mut rng = Pcg32::new(0x5EED);
    let g = residual_net(&mut rng);
    let calib: Vec<Tensor<f32>> = (0..6).map(|_| rand_image(&mut rng)).collect();
    let img = rand_image(&mut rng);
    let other = rand_image(&mut rng);
    for gamma in [1usize, 2, 4] {
        for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
            let mut ex = QuantExecutor::new(
                Arc::clone(&g),
                QuantSettings { mode, gamma, granularity: Granularity::PerTensor, ..Default::default() },
            );
            ex.calibrate(&calib);
            let t1: Vec<Vec<f32>> =
                ex.run_trace(&img).unwrap().iter().map(|t| t.data().to_vec()).collect();
            let t2: Vec<Vec<f32>> =
                ex.run_trace(&img).unwrap().iter().map(|t| t.data().to_vec()).collect();
            assert_eq!(t1, t2, "{mode:?} γ={gamma}: run_trace not reproducible");
            let mut arena = ex.make_arena();
            let a = ex.run_with_arena(&img, &mut arena).unwrap()[0].clone();
            let _ = ex.run_with_arena(&other, &mut arena).unwrap();
            let b = ex.run_with_arena(&img, &mut arena).unwrap()[0].clone();
            assert_eq!(a.data(), b.data(), "{mode:?} γ={gamma}: worker arena leaked state");
        }
    }
}

#[test]
fn quant_fused_matches_reference_outputs() {
    let mut rng = Pcg32::new(0xBEE);
    let g = residual_net(&mut rng);
    let calib: Vec<Tensor<f32>> = (0..6).map(|_| rand_image(&mut rng)).collect();
    let img = rand_image(&mut rng);
    for gran in [Granularity::PerTensor, Granularity::PerChannel] {
        for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
            let mut ex = QuantExecutor::new(
                Arc::clone(&g),
                QuantSettings { mode, granularity: gran, ..Default::default() },
            );
            ex.calibrate(&calib);
            let fast = ex.run(&img).unwrap()[0].data().to_vec();
            let slow = ex.run_reference(&img)[0].data().to_vec();
            // Fused and reference engines quantize onto the same grids;
            // differences are bounded by f32-vs-f64 accumulation noise
            // around quantization-step boundaries.
            let num: f32 = fast.iter().zip(&slow).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f32 = slow.iter().map(|v| v * v).sum::<f32>().max(1e-9);
            let rel = (num / den).sqrt();
            assert!(
                rel < 0.05,
                "{mode:?}/{gran:?}: fused vs reference rel err {rel}\nfast={fast:?}\nslow={slow:?}"
            );
        }
    }
}

#[test]
fn packed_plan_uses_fewer_buffers_than_trace() {
    let mut rng = Pcg32::new(0x11);
    let g = residual_net(&mut rng);
    let packed = memory::MemoryPlan::packed(&g);
    let trace = memory::MemoryPlan::trace(&g);
    assert!(packed.num_slots < trace.num_slots);
    assert!(packed.total_elems() < trace.total_elems());
    // Every node got a valid slot and shape.
    assert_eq!(packed.slots.len(), g.nodes().len());
    for (&s, sh) in packed.slots.iter().zip(packed.shapes.iter()) {
        assert!(s < packed.num_slots);
        assert!(packed.slot_elems[s] >= sh.numel());
    }
}
