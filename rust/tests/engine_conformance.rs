//! Engine conformance suite: one shared battery, run against **every**
//! [`pdq::engine::Engine`] implementation through trait objects — exactly
//! how the coordinator's workers see them. A new backend (PJRT runtime,
//! another bit width) passes by being added to `conformance_engines()`.
//!
//! The battery proves, per engine:
//! 1. **Determinism across sessions** — two freshly compiled sessions
//!    produce bit-identical outputs for the same inputs, and a session
//!    reused across interleaved inputs leaks no state.
//! 2. **Batch-vs-single parity** — `run_batch` equals per-image `run`
//!    bit for bit.
//! 3. **Typed errors** — a wrong-shape input is an
//!    `EngineError::ShapeMismatch`, never a panic; `input_shape()` is
//!    advertised correctly; `spec()` matches what was built.
//! 4. **Oracle parity** — fake-quant engines are bit-identical to
//!    `QuantExecutor::run` and close to the seed `run_reference`; int8
//!    engines are bit-identical to `Int8Executor::run` and (values *and*
//!    grids) to `run_naive`, the scalar CMSIS oracle; the fp32 engine is
//!    bounded against the naive `float_exec::run` reference (arena-vs-
//!    naive parity is only approximate by design — see kernel_parity).

use std::sync::Arc;

use pdq::engine::{
    Engine, EngineBuilder, EngineError, FloatEngine, Int8Engine, QuantEngine, SessionPool,
    VariantSpec,
};
use pdq::models::Model;
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{float_exec, Graph, Int8Executor, QuantMode};
use pdq::quant::Granularity;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::Pcg32;

const HW: usize = 10;
const CIN: usize = 3;

/// conv → relu → dwconv → add (residual) → relu6 → gap → linear: both conv
/// kinds plus a residual join, seeded deterministically.
fn test_graph() -> Arc<Graph> {
    let mut rng = Pcg32::new(0xC0F0);
    let mut g = Graph::new(Shape::hwc(HW, HW, CIN));
    let x = g.input();
    let w1: Vec<f32> = (0..8 * 9 * CIN).map(|_| rng.normal_ms(0.0, 0.25)).collect();
    let c1 = g.conv(
        x,
        Tensor::from_vec(Shape::ohwi(8, 3, 3, CIN), w1),
        vec![0.05; 8],
        ConvGeom::same(3, 1),
    );
    let r1 = g.relu(c1);
    let wd: Vec<f32> = (0..8 * 9).map(|_| rng.normal_ms(0.1, 0.3)).collect();
    let d1 = g.dwconv(
        r1,
        Tensor::from_vec(Shape::new(&[8, 3, 3]), wd),
        vec![0.0; 8],
        ConvGeom::same(3, 1),
    );
    let a = g.add(d1, r1);
    let r2 = g.relu6(a);
    let p = g.global_avg_pool(r2);
    let wl: Vec<f32> = (0..5 * 8).map(|_| rng.normal_ms(0.0, 0.4)).collect();
    let l = g.linear(p, Tensor::from_vec(Shape::new(&[5, 8]), wl), vec![0.0; 5]);
    g.mark_output(l);
    Arc::new(g)
}

fn calib_images() -> Vec<Tensor<f32>> {
    let mut rng = Pcg32::new(0xCA1B);
    (0..8)
        .map(|_| {
            let d: Vec<f32> = (0..HW * HW * CIN).map(|_| rng.uniform()).collect();
            Tensor::from_vec(Shape::hwc(HW, HW, CIN), d)
        })
        .collect()
}

fn test_images() -> Vec<Tensor<f32>> {
    let mut rng = Pcg32::new(0x7E57);
    (0..4)
        .map(|_| {
            let d: Vec<f32> = (0..HW * HW * CIN).map(|_| rng.uniform()).collect();
            Tensor::from_vec(Shape::hwc(HW, HW, CIN), d)
        })
        .collect()
}

fn quant_executor(mode: QuantMode, gran: Granularity) -> QuantExecutor {
    let mut ex = QuantExecutor::new(
        test_graph(),
        QuantSettings { mode, granularity: gran, ..Default::default() },
    );
    ex.calibrate(&calib_images());
    ex
}

fn int8_executor(mode: QuantMode, weight_gran: Granularity) -> Int8Executor {
    let ex = quant_executor(mode, Granularity::PerTensor);
    Int8Executor::lower(&ex, weight_gran).expect("lowering")
}

/// Every Engine implementation, as trait objects, labeled for messages.
fn conformance_engines() -> Vec<(String, Arc<dyn Engine>)> {
    let mut out: Vec<(String, Arc<dyn Engine>)> =
        vec![("fp32".into(), Arc::new(FloatEngine::new(test_graph())))];
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        for gran in [Granularity::PerTensor, Granularity::PerChannel] {
            let spec = VariantSpec::FakeQuant { mode, gran };
            out.push((
                spec.wire(),
                Arc::new(QuantEngine::new(Arc::new(quant_executor(mode, gran)))),
            ));
        }
        let spec = VariantSpec::Int8 { mode, weight_gran: Granularity::PerTensor, bits: 8 };
        out.push((
            spec.wire(),
            Arc::new(Int8Engine::new(Arc::new(int8_executor(mode, Granularity::PerTensor)))),
        ));
    }
    out
}

fn bits(outs: &[Tensor<f32>]) -> Vec<Vec<u32>> {
    outs.iter().map(|t| t.data().iter().map(|x| x.to_bits()).collect()).collect()
}

fn rel_err(a: &[f32], b: &[f32]) -> f32 {
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = b.iter().map(|v| v * v).sum::<f32>().max(1e-9);
    (num / den).sqrt()
}

/// Battery check 1: repeated sessions are deterministic and leak no state.
#[test]
fn determinism_across_repeated_sessions() {
    let imgs = test_images();
    for (name, engine) in conformance_engines() {
        let mut s1 = engine.compile().expect("session 1");
        let mut s2 = engine.compile().expect("session 2");
        // Interleave different inputs through s1 to hunt stale-state bugs,
        // then confirm it still agrees with the fresh s2 bit for bit.
        for img in &imgs {
            let _ = s1.run(img).expect("warm-up run");
        }
        for img in &imgs {
            let a = s1.run(img).expect("s1 run");
            let b = s2.run(img).expect("s2 run");
            assert_eq!(bits(&a), bits(&b), "{name}: sessions disagree");
        }
    }
}

/// Battery check 2: `run_batch` == per-image `run`, bit for bit.
#[test]
fn batch_matches_single_bit_exactly() {
    let imgs = test_images();
    for (name, engine) in conformance_engines() {
        let mut batch_session = engine.compile().expect("batch session");
        let mut single_session = engine.compile().expect("single session");
        let batched = batch_session.run_batch(&imgs).expect("run_batch");
        assert_eq!(batched.len(), imgs.len(), "{name}: batch length");
        for (img, outs) in imgs.iter().zip(&batched) {
            let single = single_session.run(img).expect("single run");
            assert_eq!(bits(outs), bits(&single), "{name}: batch != single");
        }
    }
}

/// Battery check 3: typed shape errors, advertised input shape, spec
/// agreement — uniformly, through the trait object.
#[test]
fn typed_errors_and_metadata() {
    let want_shape = Shape::hwc(HW, HW, CIN);
    for (name, engine) in conformance_engines() {
        assert_eq!(engine.input_shape(), &want_shape, "{name}: input_shape");
        let mut session = engine.compile().expect("session");
        assert_eq!(session.input_shape(), &want_shape, "{name}: session shape");
        let bad = Tensor::full(Shape::hwc(2, 2, 1), 0.0);
        match session.run(&bad) {
            Err(EngineError::ShapeMismatch { expected, got }) => {
                assert_eq!(expected, want_shape, "{name}");
                assert_eq!(got.dims(), &[2, 2, 1], "{name}");
            }
            other => panic!("{name}: want ShapeMismatch, got {:?}", other.err()),
        }
        // The session still works after a rejected input.
        let ok = session.run(&test_images()[0]).expect("run after error");
        assert_eq!(ok[0].shape().dims(), &[5], "{name}");
    }
}

/// Battery check 4a: the fp32 engine is bit-exact vs the arena float path
/// and the fake-quant engines are bit-exact vs their executor's own `run`
/// (the pre-redesign serving entry point), plus within tolerance of the
/// seed `run_reference` oracle.
#[test]
fn quant_engines_match_pre_redesign_oracles() {
    let imgs = test_images();
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        for gran in [Granularity::PerTensor, Granularity::PerChannel] {
            let ex = Arc::new(quant_executor(mode, gran));
            let engine = QuantEngine::new(Arc::clone(&ex));
            let mut session = engine.compile().expect("session");
            for img in &imgs {
                let got = session.run(img).expect("engine run");
                let direct = ex.run(img).expect("executor run");
                assert_eq!(
                    bits(&got),
                    bits(&direct),
                    "{mode:?}/{gran:?}: engine != QuantExecutor::run"
                );
                let reference = ex.run_reference(img);
                let e = rel_err(got[0].data(), reference[0].data());
                assert!(
                    e < 0.1,
                    "{mode:?}/{gran:?}: engine vs run_reference rel err {e}"
                );
            }
        }
    }
}

/// Battery check 4b: the fp32 engine vs the reference float executor, and
/// the int8 engines vs the naive scalar CMSIS oracle (`run_naive`) — the
/// quantized outputs and grids must agree exactly, and the engine's f32
/// outputs must be bit-identical to the executor's own dequantization.
#[test]
fn fp32_and_int8_engines_match_reference_oracles() {
    let imgs = test_images();
    let g = test_graph();
    let fp = FloatEngine::new(Arc::clone(&g));
    let mut fp_session = fp.compile().expect("fp session");
    for img in &imgs {
        let got = fp_session.run(img).expect("fp run");
        // The arena float engine's parity with the naive reference engine
        // is bounded (kernel_parity pins it); here we assert the *engine*
        // adds nothing on top of the arena path it wraps.
        let reference = float_exec::run(&g, img);
        let e = rel_err(got[0].data(), reference[0].data());
        assert!(e < 1e-4, "fp32 engine vs reference executor rel err {e}");
    }
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        let ex = Arc::new(int8_executor(mode, Granularity::PerTensor));
        let engine = Int8Engine::new(Arc::clone(&ex));
        let mut session = engine.compile().expect("session");
        for img in &imgs {
            let got = session.run(img).expect("engine run");
            assert_eq!(bits(&got), bits(&ex.run(img).expect("executor run")), "{mode:?}");
            // The scalar CMSIS ports are the hard oracle: values AND grids.
            let naive = ex.run_naive(img);
            let fast = ex.run_q(img).expect("run_q");
            assert_eq!(naive.len(), fast.len(), "{mode:?}");
            for ((tn, qn), (tf, qf)) in naive.iter().zip(fast.iter()) {
                assert_eq!(qn, qf, "{mode:?}: grid mismatch vs scalar oracle");
                assert_eq!(tn.data(), tf.data(), "{mode:?}: values differ vs scalar oracle");
            }
        }
    }
}

/// The builder constructs bit-identical engines to manual wiring when fed
/// the same calibration set — i.e. `EngineBuilder` truly subsumes the old
/// construction paths.
#[test]
fn builder_is_bit_identical_to_manual_construction() {
    let model = Model {
        name: "conf".into(),
        task: pdq::data::Task::Cls,
        graph: test_graph(),
        num_outputs: 1,
        golden: None,
        hlo_path: None,
    };
    let calib = calib_images();
    let imgs = test_images();
    for spec in [
        VariantSpec::Fp32,
        VariantSpec::FakeQuant { mode: QuantMode::Probabilistic, gran: Granularity::PerChannel },
        VariantSpec::Int8 { mode: QuantMode::Static, weight_gran: Granularity::PerChannel, bits: 8 },
    ] {
        let built = EngineBuilder::new(&model)
            .spec(spec)
            .calibration_images(&calib)
            .build()
            .expect("builder builds");
        assert_eq!(built.spec(), spec);
        let manual: Arc<dyn Engine> = match spec {
            VariantSpec::Fp32 => Arc::new(FloatEngine::new(test_graph())),
            VariantSpec::FakeQuant { mode, gran } => {
                Arc::new(QuantEngine::new(Arc::new(quant_executor(mode, gran))))
            }
            VariantSpec::Int8 { mode, weight_gran, bits: _ } => {
                Arc::new(Int8Engine::new(Arc::new(int8_executor(mode, weight_gran))))
            }
        };
        let mut sb = built.compile().expect("built session");
        let mut sm = manual.compile().expect("manual session");
        for img in &imgs {
            assert_eq!(
                bits(&sb.run(img).expect("built run")),
                bits(&sm.run(img).expect("manual run")),
                "{}: builder output differs from manual construction",
                spec.wire()
            );
        }
    }
}

/// Round trip through the compiled artifact format: pack the demo model,
/// load the bytes back, and compare every one of the 13 menu cells
/// bit-for-bit against the in-process `standard_menu` build of the same
/// model. This is the artifact contract — a `.pdqa` file serves exactly
/// what the process it was packed from would have served, across fp32,
/// all three fake-quant modes and all nine int8 mode×rung cells.
#[test]
fn artifact_roundtrip_is_bit_exact_with_standard_menu() {
    use pdq::artifact::{pack_model, ArtifactEngine, PackOptions};
    use pdq::coordinator::calibrate::demo_model;
    use pdq::engine::standard_menu;

    let model = demo_model("conf_artifact");
    let bytes = pack_model(&model, PackOptions::default()).expect("pack");
    let loaded = ArtifactEngine::from_bytes(&bytes).expect("load");
    let reference = standard_menu(&model).expect("in-process menu");
    assert_eq!(loaded.menu().len(), reference.len(), "menu sizes");

    let mut rng = Pcg32::new(0xA27F);
    let imgs: Vec<Tensor<f32>> = (0..3)
        .map(|_| {
            let d: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.uniform()).collect();
            Tensor::from_vec(Shape::hwc(32, 32, 3), d)
        })
        .collect();
    for ((ka, ea), (kr, er)) in loaded.menu().iter().zip(&reference) {
        assert_eq!(ka, kr, "menu cells must line up in canonical order");
        let mut sa = ea.compile().expect("artifact session");
        let mut sr = er.compile().expect("reference session");
        for img in &imgs {
            assert_eq!(
                bits(&sa.run(img).expect("artifact run")),
                bits(&sr.run(img).expect("reference run")),
                "{}: artifact engine diverged from the in-process build",
                ka.wire()
            );
        }
    }
}

/// The worker-facing pool serves every engine deterministically and
/// actually reuses sessions.
#[test]
fn session_pool_reuses_and_stays_deterministic() {
    let imgs = test_images();
    for (name, engine) in conformance_engines() {
        let pool = SessionPool::new(Arc::clone(&engine));
        let first = {
            let mut s = pool.acquire().expect("acquire");
            s.run(&imgs[0]).expect("run")
        };
        for _ in 0..3 {
            let mut s = pool.acquire().expect("acquire");
            let again = s.run(&imgs[0]).expect("run");
            assert_eq!(bits(&first), bits(&again), "{name}: pooled session drifted");
        }
        assert_eq!(pool.idle(), 1, "{name}: sequential checkouts must reuse one session");
    }
}
