//! Bench/regen target for Fig. 4 (γ sensitivity of the accuracy).

use std::path::Path;

use pdq::harness::experiments::{fig4, ExpOptions};

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("bench_fig4: skipped (run `make artifacts` first)");
        return;
    }
    let opts = ExpOptions { n_test: 60, ..Default::default() };
    let t0 = std::time::Instant::now();
    let table = fig4(artifacts, &opts).expect("fig4");
    println!("# Fig. 4 — sampling stride sensitivity (n={})\n", opts.n_test);
    println!("{}", table.to_markdown());
    println!("bench_fig4: total {:.1}s", t0.elapsed().as_secs_f64());
}
