//! Bench/regen target for Fig. 5 (calibration-set size sweep).

use std::path::Path;

use pdq::harness::experiments::{fig5, ExpOptions};

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("bench_fig5: skipped (run `make artifacts` first)");
        return;
    }
    let opts = ExpOptions { n_test: 60, ..Default::default() };
    let t0 = std::time::Instant::now();
    let table = fig5(artifacts, &opts).expect("fig5");
    println!("# Fig. 5 — calibration set size (n={})\n", opts.n_test);
    println!("{}", table.to_markdown());
    println!("bench_fig5: total {:.1}s", t0.elapsed().as_secs_f64());
}
