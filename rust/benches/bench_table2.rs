//! Bench/regen target for Table 2 (out-of-domain, corruption suite).

use std::path::Path;

use pdq::harness::experiments::{table2, ExpOptions};

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("bench_table2: skipped (run `make artifacts` first)");
        return;
    }
    let opts = ExpOptions { n_test: 60, ..Default::default() };
    let t0 = std::time::Instant::now();
    let (table, json) = table2(artifacts, &opts).expect("table2");
    println!("# Table 2 — Out-of-Domain (n={})\n", opts.n_test);
    println!("{}", table.to_markdown());
    println!("BENCH_JSON {}", json.to_string_compact());
    println!("bench_table2: total {:.1}s", t0.elapsed().as_secs_f64());
}
