//! Hot-path microbenchmarks (the §Perf iteration targets):
//! estimator window sums (naive vs integral), the fixed-point estimator,
//! the fake-quant executor, and coordinator round-trip overhead.

use std::sync::Arc;
use std::time::Duration;

use pdq::coordinator::calibrate::ExecKind;
use pdq::coordinator::router::{ModeKey, VariantKey};
use pdq::coordinator::{Server, ServerConfig};
use pdq::estimator::conv::{window_sums_integral, window_sums_naive};
use pdq::estimator::fixed::FixedEstimator;
use pdq::estimator::WeightStats;
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{Graph, QuantMode};
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::bench::{black_box, Bencher};
use pdq::util::Pcg32;

fn rand_image(rng: &mut Pcg32, h: usize, w: usize, c: usize) -> Tensor<f32> {
    let data: Vec<f32> = (0..h * w * c).map(|_| rng.normal_ms(0.2, 0.8)).collect();
    Tensor::from_vec(Shape::hwc(h, w, c), data)
}

fn main() {
    let mut rng = Pcg32::new(11);
    let x = rand_image(&mut rng, 32, 32, 16);
    let geom = ConvGeom::same(3, 1);
    let mut bench = Bencher::new(Duration::from_millis(100), Duration::from_millis(700), 50_000);

    // Estimation stage: naive (paper's loop) vs integral-image fast path.
    for gamma in [1usize, 4] {
        bench.bench(&format!("estimator/window_sums_naive_g{gamma}"), 1.0, || {
            black_box(window_sums_naive(&x, &geom, gamma));
        });
        bench.bench(&format!("estimator/window_sums_integral_g{gamma}"), 1.0, || {
            black_box(window_sums_integral(&x, &geom, gamma));
        });
    }

    // Full conv estimate (integral path).
    let ws = WeightStats { mu: 0.05, var: 0.02, mu_ch: vec![], var_ch: vec![], fan_in: 144 };
    bench.bench("estimator/estimate_conv", 1.0, || {
        black_box(pdq::estimator::conv::estimate(&x, &ws, &geom, 1));
    });

    // Integer-only estimator.
    let fe = FixedEstimator::new(0.05, 0.02, 1.0 / 255.0);
    let q: Vec<i8> = (0..4096).map(|_| rng.int_range(-128, 127) as i8).collect();
    bench.bench("estimator/fixed_linear_4096", 1.0, || {
        black_box(fe.estimate_linear(&q, -3));
    });

    // Quantized executor forward (small residual net).
    let graph = {
        let mut g = Graph::new(Shape::hwc(32, 32, 3));
        let xin = g.input();
        let w1: Vec<f32> = (0..16 * 9 * 3).map(|_| rng.normal_ms(0.0, 0.2)).collect();
        let c1 = g.conv(xin, Tensor::from_vec(Shape::ohwi(16, 3, 3, 3), w1), vec![0.0; 16], geom);
        let r1 = g.relu(c1);
        let w2: Vec<f32> = (0..16 * 9 * 16).map(|_| rng.normal_ms(0.0, 0.1)).collect();
        let c2 = g.conv(r1, Tensor::from_vec(Shape::ohwi(16, 3, 3, 16), w2), vec![0.0; 16], geom);
        let a = g.add(c2, r1);
        let r2 = g.relu(a);
        let p = g.global_avg_pool(r2);
        let wl: Vec<f32> = (0..10 * 16).map(|_| rng.normal_ms(0.0, 0.3)).collect();
        let l = g.linear(p, Tensor::from_vec(Shape::new(&[10, 16]), wl), vec![0.0; 10]);
        g.mark_output(l);
        Arc::new(g)
    };
    let img = rand_image(&mut rng, 32, 32, 3);
    let calib: Vec<Tensor<f32>> = (0..4).map(|_| rand_image(&mut rng, 32, 32, 3)).collect();
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        let mut ex = QuantExecutor::new(Arc::clone(&graph), QuantSettings { mode, ..Default::default() });
        ex.calibrate(&calib);
        bench.bench(&format!("quant_exec/forward_{}", mode.label()), 1.0, || {
            black_box(ex.run(&img));
        });
    }

    // Coordinator round trip: submit -> batch -> execute -> reply.
    let mut g = Graph::new(Shape::hwc(8, 8, 1));
    let xin = g.input();
    let r = g.relu(xin);
    g.mark_output(r);
    let key = VariantKey { model: "echo".into(), mode: ModeKey::Fp32 };
    let server = Server::start(
        vec![(key.clone(), ExecKind::Float(Arc::new(g)))],
        ServerConfig::default(),
    );
    let small = Tensor::full(Shape::hwc(8, 8, 1), 1.0f32);
    bench.bench("coordinator/round_trip", 1.0, || {
        let rx = server.submit(key.clone(), 0, small.clone()).unwrap();
        black_box(rx.recv().unwrap());
    });
    drop(server.shutdown());
}
