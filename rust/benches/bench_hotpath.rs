//! Hot-path microbenchmarks (the §Perf iteration targets): estimator
//! window sums (naive vs integral), the full conv estimate (seed
//! implementation vs arena fast path), the fixed-point estimator, the
//! fake-quant executor (seed reference engine vs fused arena engine), and
//! coordinator round-trip overhead.
//!
//! Emits a machine-readable report to `BENCH_hotpath.json` (see
//! EXPERIMENTS.md §Perf) with the headline speedup ratios in `derived`.

use std::sync::Arc;
use std::time::Duration;

use pdq::coordinator::{Server, ServerConfig};
use pdq::engine::{FloatEngine, VariantKey, VariantSpec};
use pdq::estimator::conv::{
    estimate_from_window_sums, window_sums_integral, window_sums_naive,
    window_sums_integral_scratch, WindowSums,
};
use pdq::estimator::fixed::FixedEstimator;
use pdq::estimator::{EstimatorScratch, Moments, WeightStats};
use pdq::nn::quant_exec::{QuantExecutor, QuantSettings};
use pdq::nn::{Graph, Int8Executor, QuantMode};
use pdq::quant::Granularity;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::bench::{black_box, Bencher};
use pdq::util::Pcg32;

fn rand_image(rng: &mut Pcg32, h: usize, w: usize, c: usize) -> Tensor<f32> {
    let data: Vec<f32> = (0..h * w * c).map(|_| rng.normal_ms(0.2, 0.8)).collect();
    Tensor::from_vec(Shape::hwc(h, w, c), data)
}

/// The seed's integral-image window sums, preserved verbatim as the perf
/// baseline: per-pixel `px()` index arithmetic and fresh allocations per
/// call (what `window_sums_integral` did before the arena/scratch rework).
fn seed_window_sums_integral(x: &Tensor<f32>, geom: &ConvGeom, gamma: usize) -> WindowSums {
    let (h, w, c) = (x.shape().dim(0), x.shape().dim(1), x.shape().dim(2));
    let (oh, ow) = geom.out_dims(h, w);
    let iw = w + 1;
    let mut i1 = vec![0.0f64; (h + 1) * iw];
    let mut i2 = vec![0.0f64; (h + 1) * iw];
    for y in 0..h {
        let mut row1 = 0.0f64;
        let mut row2 = 0.0f64;
        for xx in 0..w {
            let mut cs = 0.0f64;
            let mut cs2 = 0.0f64;
            for ch in 0..c {
                let v = x.px(y, xx, ch) as f64;
                cs += v;
                cs2 += v * v;
            }
            row1 += cs;
            row2 += cs2;
            i1[(y + 1) * iw + xx + 1] = i1[y * iw + xx + 1] + row1;
            i2[(y + 1) * iw + xx + 1] = i2[y * iw + xx + 1] + row2;
        }
    }
    let rect = |img: &[f64], y0: usize, y1: usize, x0: usize, x1: usize| -> f64 {
        img[y1 * iw + x1] - img[y0 * iw + x1] - img[y1 * iw + x0] + img[y0 * iw + x0]
    };
    let mut s1 = Vec::new();
    let mut s2 = Vec::new();
    let mut oy = 0;
    while oy < oh {
        let (y0, y1) = geom.in_range_y(oy, h);
        let mut ox = 0;
        while ox < ow {
            let (x0, x1) = geom.in_range_x(ox, w);
            s1.push(rect(&i1, y0, y1, x0, x1));
            s2.push(rect(&i2, y0, y1, x0, x1));
            ox += gamma;
        }
        oy += gamma;
    }
    WindowSums { s1, s2 }
}

/// The seed's `estimate`: seed window sums + closed-form pooling.
fn seed_estimate(x: &Tensor<f32>, ws: &WeightStats, geom: &ConvGeom, gamma: usize) -> Moments {
    let sums = seed_window_sums_integral(x, geom, gamma);
    estimate_from_window_sums(&sums, ws.mu, ws.var)
}

fn main() {
    let mut rng = Pcg32::new(11);
    let x = rand_image(&mut rng, 32, 32, 16);
    let geom = ConvGeom::same(3, 1);
    let mut bench = Bencher::new(Duration::from_millis(100), Duration::from_millis(700), 50_000);

    // Estimation stage: naive (paper's loop) vs integral-image fast path.
    for gamma in [1usize, 4] {
        bench.bench(&format!("estimator/window_sums_naive_g{gamma}"), 1.0, || {
            black_box(window_sums_naive(&x, &geom, gamma));
        });
        bench.bench(&format!("estimator/window_sums_integral_g{gamma}"), 1.0, || {
            black_box(window_sums_integral(&x, &geom, gamma));
        });
    }

    // Full conv estimate: seed implementation vs arena-scratch fast path.
    let ws = WeightStats { mu: 0.05, var: 0.02, mu_ch: vec![], var_ch: vec![], fan_in: 144 };
    bench.bench("estimator/estimate_conv_seed", 1.0, || {
        black_box(seed_estimate(&x, &ws, &geom, 1));
    });
    let mut scratch = EstimatorScratch::default();
    bench.bench("estimator/estimate_conv", 1.0, || {
        window_sums_integral_scratch(&x, &geom, 1, &mut scratch);
        black_box(estimate_from_window_sums(&scratch.sums, ws.mu, ws.var));
    });

    // Integer-only estimator.
    let fe = FixedEstimator::new(0.05, 0.02, 1.0 / 255.0);
    let q: Vec<i8> = (0..4096).map(|_| rng.int_range(-128, 127) as i8).collect();
    bench.bench("estimator/fixed_linear_4096", 1.0, || {
        black_box(fe.estimate_linear(&q, -3));
    });

    // Quantized executor forward (small residual net): the fused arena
    // engine vs the seed reference engine (fresh tensors, naive kernels,
    // separate requantize pass).
    let graph = {
        let mut g = Graph::new(Shape::hwc(32, 32, 3));
        let xin = g.input();
        let w1: Vec<f32> = (0..16 * 9 * 3).map(|_| rng.normal_ms(0.0, 0.2)).collect();
        let c1 = g.conv(xin, Tensor::from_vec(Shape::ohwi(16, 3, 3, 3), w1), vec![0.0; 16], geom);
        let r1 = g.relu(c1);
        let w2: Vec<f32> = (0..16 * 9 * 16).map(|_| rng.normal_ms(0.0, 0.1)).collect();
        let c2 = g.conv(r1, Tensor::from_vec(Shape::ohwi(16, 3, 3, 16), w2), vec![0.0; 16], geom);
        let a = g.add(c2, r1);
        let r2 = g.relu(a);
        let p = g.global_avg_pool(r2);
        let wl: Vec<f32> = (0..10 * 16).map(|_| rng.normal_ms(0.0, 0.3)).collect();
        let l = g.linear(p, Tensor::from_vec(Shape::new(&[10, 16]), wl), vec![0.0; 10]);
        g.mark_output(l);
        Arc::new(g)
    };
    let img = rand_image(&mut rng, 32, 32, 3);
    let calib: Vec<Tensor<f32>> = (0..4).map(|_| rand_image(&mut rng, 32, 32, 3)).collect();
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        let mut ex = QuantExecutor::new(Arc::clone(&graph), QuantSettings { mode, ..Default::default() });
        ex.calibrate(&calib);
        bench.bench(&format!("quant_exec/forward_{}_seed", mode.label()), 1.0, || {
            black_box(ex.run_reference(&img));
        });
        bench.bench(&format!("quant_exec/forward_{}", mode.label()), 1.0, || {
            black_box(ex.run(&img).unwrap());
        });
        let mut arena = ex.make_arena();
        bench.bench(&format!("quant_exec/forward_{}_worker_arena", mode.label()), 1.0, || {
            black_box(ex.run_with_arena(&img, &mut arena).unwrap());
        });
    }

    // True-int8 engine (§5.1 at serving speed): naive-cmsis baseline
    // (scalar kernels, fresh tensors, separate requantize sweep) vs the
    // fast int8 engine (im2col + blocked i8 GEMM, fused requant epilogue,
    // arena buffers) vs the f32 fused engine — per requant mode. Reported
    // separately in BENCH_int8.json.
    let mut b8 = Bencher::new(Duration::from_millis(100), Duration::from_millis(700), 50_000);
    for mode in [QuantMode::Static, QuantMode::Dynamic, QuantMode::Probabilistic] {
        let mut ex = QuantExecutor::new(Arc::clone(&graph), QuantSettings { mode, ..Default::default() });
        ex.calibrate(&calib);
        let int8 = Int8Executor::lower(&ex, Granularity::PerTensor).expect("int8 lowering");
        b8.bench(&format!("int8/forward_{}_naive", mode.label()), 1.0, || {
            black_box(int8.run_naive(&img));
        });
        let mut arena = int8.make_arena();
        b8.bench(&format!("int8/forward_{}", mode.label()), 1.0, || {
            black_box(int8.run_q_with_arena(&img, &mut arena).unwrap());
        });
        b8.bench(&format!("int8/forward_{}_f32fast", mode.label()), 1.0, || {
            black_box(ex.run(&img).unwrap());
        });
    }
    let mut derived8: Vec<(&str, f64)> = Vec::new();
    let pairs8 = [
        ("speedup_int8_naive_vs_fast_static", "int8/forward_static_naive", "int8/forward_static"),
        ("speedup_int8_naive_vs_fast_dynamic", "int8/forward_dynamic_naive", "int8/forward_dynamic"),
        ("speedup_int8_naive_vs_fast_ours", "int8/forward_ours_naive", "int8/forward_ours"),
        ("speedup_f32fast_vs_int8_static", "int8/forward_static_f32fast", "int8/forward_static"),
        ("speedup_f32fast_vs_int8_dynamic", "int8/forward_dynamic_f32fast", "int8/forward_dynamic"),
        ("speedup_f32fast_vs_int8_ours", "int8/forward_ours_f32fast", "int8/forward_ours"),
    ];
    for (name, slow, fast) in pairs8 {
        if let Some(s) = b8.speedup(slow, fast) {
            println!("derived {name}: {s:.2}x");
            derived8.push((name, s));
        }
    }
    match b8.save_json("BENCH_int8.json", &derived8) {
        Ok(()) => println!("wrote BENCH_int8.json"),
        Err(e) => eprintln!("could not write BENCH_int8.json: {e}"),
    }

    // Coordinator round trip: submit -> batch -> execute -> reply.
    let mut g = Graph::new(Shape::hwc(8, 8, 1));
    let xin = g.input();
    let r = g.relu(xin);
    g.mark_output(r);
    let key = VariantKey::new("echo", VariantSpec::Fp32);
    let server = Server::start(
        vec![(key.clone(), Arc::new(FloatEngine::new(Arc::new(g))))],
        ServerConfig::default(),
    );
    let small = Tensor::full(Shape::hwc(8, 8, 1), 1.0f32);
    bench.bench("coordinator/round_trip", 1.0, || {
        let rx = server.submit(key.clone(), 0, small.clone()).unwrap();
        black_box(rx.recv().unwrap());
    });
    drop(server.shutdown());

    // Headline ratios for the perf trajectory (EXPERIMENTS.md §Perf).
    let mut derived: Vec<(&str, f64)> = Vec::new();
    let pairs = [
        ("speedup_forward_ours", "quant_exec/forward_ours_seed", "quant_exec/forward_ours"),
        ("speedup_forward_static", "quant_exec/forward_static_seed", "quant_exec/forward_static"),
        (
            "speedup_forward_dynamic",
            "quant_exec/forward_dynamic_seed",
            "quant_exec/forward_dynamic",
        ),
        ("speedup_estimate_conv", "estimator/estimate_conv_seed", "estimator/estimate_conv"),
        (
            "speedup_window_sums_g1",
            "estimator/window_sums_naive_g1",
            "estimator/window_sums_integral_g1",
        ),
    ];
    for (name, slow, fast) in pairs {
        if let Some(s) = bench.speedup(slow, fast) {
            println!("derived {name}: {s:.2}x");
            derived.push((name, s));
        }
    }
    match bench.save_json("BENCH_hotpath.json", &derived) {
        Ok(()) => println!("wrote BENCH_hotpath.json"),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
}
