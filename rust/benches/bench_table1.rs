//! Bench/regen target for Table 1 (in-domain accuracy comparison).
//!
//! Regenerates the table on a reduced test set and times the per-variant
//! evaluation cost (the paper's Table 1 rows, same column layout).

use std::path::Path;

use pdq::harness::experiments::{table1, ExpOptions};

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("bench_table1: skipped (run `make artifacts` first)");
        return;
    }
    let opts = ExpOptions { n_test: 60, ..Default::default() };
    let t0 = std::time::Instant::now();
    let (table, json) = table1(artifacts, &opts).expect("table1");
    println!("# Table 1 — In-Domain (n={})\n", opts.n_test);
    println!("{}", table.to_markdown());
    println!("BENCH_JSON {}", json.to_string_compact());
    println!("bench_table1: total {:.1}s", t0.elapsed().as_secs_f64());
}
