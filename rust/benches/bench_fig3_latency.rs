//! Bench target for Fig. 3: the MCU cycle-model sweeps *and* wall-clock of
//! the true-int8 CMSIS wrappers (estimation vs conv vs dynamic overhead).

use std::time::Duration;

use pdq::cmsis::pdq_wrappers::{conv_dynamic, conv_pdq, conv_static, ConvLayerS8, QOut};
use pdq::estimator::IntervalSpec;
use pdq::harness::experiments::fig3;
use pdq::tensor::{ConvGeom, Shape, Tensor};
use pdq::util::bench::{black_box, Bencher};
use pdq::util::Pcg32;

fn main() {
    // (1) The modeled Cortex-M4 series (the actual figure).
    let (a, b, c) = fig3();
    println!("# Fig. 3a\n\n{}", a.to_markdown());
    println!("# Fig. 3b\n\n{}", b.to_markdown());
    println!("# Fig. 3c\n\n{}", c.to_markdown());

    // (2) Host wall-clock of the int8 kernels (shape 32x32x16 -> 16).
    let mut rng = Pcg32::new(5);
    let (h, w, cin, cout) = (32usize, 32, 16, 16);
    let wts: Vec<f32> = (0..cout * 9 * cin).map(|_| rng.normal_ms(0.0, 0.15)).collect();
    let wt = Tensor::from_vec(Shape::ohwi(cout, 3, 3, cin), wts);
    let s_in = 1.0f32 / 255.0;
    let mut layer = ConvLayerS8::from_float(&wt, &vec![0.0; cout], ConvGeom::same(3, 1), s_in);
    layer.interval = IntervalSpec { alpha: 4.0, beta: 4.0 };
    let xq: Vec<i8> = (0..h * w * cin)
        .map(|_| ((rng.uniform() * 255.0) as i32 - 128).clamp(-128, 127) as i8)
        .collect();
    let x = Tensor::from_vec(Shape::hwc(h, w, cin), xq);

    let mut bench = Bencher::new(Duration::from_millis(100), Duration::from_millis(800), 2000);
    bench.bench("cmsis/conv_static", 1.0, || {
        black_box(conv_static(&layer, &x, s_in, -128, QOut::from_range(-4.0, 4.0)));
    });
    bench.bench("cmsis/conv_dynamic", 1.0, || {
        black_box(conv_dynamic(&layer, &x, s_in, -128));
    });
    for gamma in [1usize, 4, 16] {
        bench.bench(&format!("cmsis/conv_pdq_gamma{gamma}"), 1.0, || {
            black_box(conv_pdq(&layer, &x, s_in, -128, gamma));
        });
    }
}
